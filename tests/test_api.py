"""The PR 9 SweepSpec facade (``repro.net.api``).

The frozen spec + ``simulate`` path must be result-identical to the
deprecated keyword forms of ``simulate_round_sweep``/
``simulate_timeline_sweep`` (which now warn and delegate), the
builders must compose specs without mutation, ``validate()`` must
reject malformed bundles with actionable errors, and the curated
``repro.net.__all__`` plus the job-aware stream keys are pinned.
"""
import warnings

import numpy as np
import pytest

import repro.net as net
from repro.core.slicing import ClientProfile
from repro.kernels.traffic import ops
from repro.net import (
    FaultSchedule,
    FLRoundWorkload,
    JobSpec,
    PONConfig,
    SweepCase,
    SweepSpec,
    TimelineSchedule,
    simulate,
    simulate_round_sweep,
    simulate_timeline_sweep,
)

CFG = PONConfig(n_onus=8, line_rate_bps=1e9)


def _clients(n=6, seed=0):
    rng = np.random.default_rng(seed)
    return [
        ClientProfile(client_id=i,
                      t_ud=float(rng.uniform(0.05, 0.5)), t_dl=0.0,
                      m_ud_bits=float(rng.uniform(1e5, 1e6)))
        for i in range(n)
    ]


def _cases(n=6):
    wl = FLRoundWorkload(clients=_clients(n), model_bits=5e5)
    return tuple(
        SweepCase(workload=wl, load=0.6, policy=policy, seed=0)
        for policy in ("fcfs", "bs")
    )


class TestSpecKwargEquivalence:
    def test_round_sweep(self):
        cases = _cases()
        spec = SweepSpec(cases=cases, pon=CFG)
        new = simulate(spec)
        with pytest.warns(DeprecationWarning, match="SweepSpec"):
            old = simulate_round_sweep(CFG, list(cases))
        assert [r.sync_time for r in new] == [r.sync_time for r in old]
        assert [r.ul_done for r in new] == [r.ul_done for r in old]

    def test_round_sweep_knobs(self):
        cases = _cases()
        spec = SweepSpec(cases=cases, pon=CFG, ul_deadline_s=2.0,
                         t_round_hint=5.0)
        new = simulate(spec)
        with pytest.warns(DeprecationWarning):
            old = simulate_round_sweep(CFG, list(cases),
                                       ul_deadline_s=2.0,
                                       t_round_hint=5.0)
        assert [r.sync_time for r in new] == [r.sync_time for r in old]

    def test_timeline_sweep(self):
        cases = _cases()
        sched = TimelineSchedule(n_rounds=3)
        spec = SweepSpec(cases=cases, pon=CFG, schedule=sched)
        new = simulate(spec)
        with pytest.warns(DeprecationWarning, match="SweepSpec"):
            old = simulate_timeline_sweep(CFG, list(cases), sched)
        for a, b in zip(new, old):
            assert list(a.sync_times) == list(b.sync_times)
            assert a.total_time_s == b.total_time_s

    def test_spec_through_wrappers_no_warning(self):
        """Passing a spec to the legacy names is the blessed path."""
        cases = _cases()
        spec = SweepSpec(cases=cases, pon=CFG)
        tspec = spec.with_schedule(TimelineSchedule(n_rounds=2))
        with warnings.catch_warnings():
            warnings.simplefilter("error", DeprecationWarning)
            r1 = simulate_round_sweep(spec)
            r2 = simulate_round_sweep(CFG, spec)
            t1 = simulate_timeline_sweep(tspec)
            t2 = simulate_timeline_sweep(CFG, tspec)
        assert [r.sync_time for r in r1] == [
            r.sync_time for r in simulate(spec)
        ]
        assert [r.sync_time for r in r2] == [r.sync_time for r in r1]
        assert [list(t.sync_times) for t in t1] == [
            list(t.sync_times) for t in simulate(tspec)
        ]
        assert [t.total_time_s for t in t2] == [
            t.total_time_s for t in t1
        ]

    def test_wrapper_schedule_mismatch(self):
        spec = SweepSpec(cases=_cases(), pon=CFG)
        with pytest.raises(ValueError, match="schedule"):
            simulate_round_sweep(
                spec.with_schedule(TimelineSchedule(n_rounds=2))
            )
        with pytest.raises(ValueError, match="schedule"):
            simulate_timeline_sweep(spec)

    def test_wrapper_double_cases(self):
        spec = SweepSpec(cases=_cases(), pon=CFG)
        with pytest.raises(TypeError):
            simulate_round_sweep(spec, list(_cases()))

    def test_explicit_cfg_overrides_spec_pon(self):
        cases = _cases()
        big = PONConfig(n_onus=8, line_rate_bps=2e9)
        spec = SweepSpec(cases=cases, pon=CFG)
        a = simulate(spec, big)
        b = simulate(SweepSpec(cases=cases, pon=big))
        assert [r.sync_time for r in a] == [r.sync_time for r in b]


class TestValidate:
    def test_needs_cases(self):
        with pytest.raises(ValueError, match="at least one case"):
            SweepSpec().validate()

    def test_case_type(self):
        with pytest.raises(TypeError, match=r"cases\[0\]"):
            SweepSpec(cases=("nope",)).validate()

    def test_bad_policy_and_fairness(self):
        wl = FLRoundWorkload(clients=_clients(), model_bits=5e5)
        bad = SweepCase(workload=wl, load=0.5, policy="edf")
        with pytest.raises(ValueError, match="unknown policy"):
            SweepSpec(cases=(bad,)).validate()
        bad = SweepCase(workload=wl, load=0.5, policy="bs",
                        fairness="lottery")
        with pytest.raises(ValueError, match="unknown fairness"):
            SweepSpec(cases=(bad,)).validate()

    def test_bad_mode_backend(self):
        with pytest.raises(ValueError, match="unknown mode"):
            SweepSpec(cases=_cases(),
                      schedule=TimelineSchedule(n_rounds=1),
                      mode="eager").validate()
        with pytest.raises(ValueError, match="unknown backend"):
            SweepSpec(cases=_cases(), backend="torch").validate()

    def test_mode_needs_schedule(self):
        with pytest.raises(ValueError, match="timeline knob"):
            SweepSpec(cases=_cases(), mode="folded").validate()

    def test_deadline_knobs_clash_with_schedule(self):
        with pytest.raises(ValueError, match="from the schedule"):
            SweepSpec(cases=_cases(),
                      schedule=TimelineSchedule(n_rounds=1),
                      ul_deadline_s=1.0).validate()

    def test_pon_type(self):
        with pytest.raises(TypeError, match="PONConfig"):
            SweepSpec(cases=_cases(), pon="gpon").validate()

    def test_simulate_rejects_non_spec(self):
        with pytest.raises(TypeError, match="SweepSpec"):
            simulate(list(_cases()))

    def test_job_errors_carry_case_index(self):
        wl = FLRoundWorkload(clients=_clients(4), model_bits=5e5)
        jobs = (JobSpec(job_id=0, clients=(0, 1), model_bits=1e5),)
        bad = SweepCase(workload=wl, load=0.5, policy="fcfs",
                        jobs=jobs)
        with pytest.raises(ValueError, match=r"cases\[0\]"):
            SweepSpec(cases=(bad,)).validate()


class TestBuilders:
    def test_single_job(self):
        spec = SweepSpec.single_job(_clients(4), 1e6, load=0.4,
                                    policy="fcfs", seed=3, pon=CFG)
        spec.validate()
        assert len(spec.cases) == 1
        case = spec.cases[0]
        assert (case.policy, case.load, case.seed) == ("fcfs", 0.4, 3)
        assert case.workload.model_bits == 1e6
        assert spec.pon is CFG

    def test_with_schedule_and_faults(self):
        sched = TimelineSchedule(n_rounds=4)
        faults = FaultSchedule(dropout_rate=0.1)
        spec = SweepSpec(cases=_cases()).with_schedule(sched)
        spec2 = spec.with_faults(faults)
        assert spec.schedule.faults is None      # frozen: no mutation
        assert spec2.schedule.faults is faults
        assert spec2.schedule.n_rounds == 4

    def test_with_faults_needs_schedule(self):
        with pytest.raises(ValueError, match="with_schedule"):
            SweepSpec(cases=_cases()).with_faults(
                FaultSchedule(dropout_rate=0.1)
            )

    def test_with_jobs(self):
        jobs = (
            JobSpec(job_id=0, clients=(0, 1, 2), model_bits=5e5),
            JobSpec(job_id=1, clients=(3, 4, 5), model_bits=2e5),
        )
        spec = SweepSpec(cases=_cases()).with_jobs(
            jobs, fairness="weighted"
        )
        spec.validate()
        assert all(c.jobs == jobs for c in spec.cases)
        assert all(c.fairness == "weighted" for c in spec.cases)


class TestCuratedSurface:
    def test_all_resolves(self):
        for name in net.__all__:
            assert not name.startswith("_")
            assert hasattr(net, name), name

    def test_key_names_exported(self):
        for name in ("SweepSpec", "simulate", "SweepCase", "JobSpec",
                     "JobRoundStats", "job_fair_split",
                     "FAIRNESS_POLICIES", "make_competing_jobs",
                     "simulate_jobs_round_reference",
                     "DEADLINE_POLICIES", "simulate_round_sweep",
                     "simulate_timeline_sweep"):
            assert name in net.__all__, name

    def test_internal_drivers_not_exported(self):
        assert "_round_sweep" not in net.__all__
        assert "_timeline_sweep" not in net.__all__


class TestJobStreamKeys:
    def test_job0_bitwise_legacy(self):
        for seed, phase, rnd, pon in ((7, 1, 3, 0), (3, 0, 5, 2)):
            legacy = ops.make_stream_key(seed, phase, rnd, pon=pon)
            keyed = ops.make_stream_key(seed, phase, rnd, pon=pon,
                                        job=0)
            assert np.array_equal(legacy, keyed)

    def test_pinned_fingerprints(self):
        pins = {
            (7, 1, 3, 0, 0): (7, 7),
            (7, 1, 3, 0, 1): (3266489916, 668265270),
            (7, 1, 3, 1, 2): (1375963586, 1798376440),
            (3, 0, 0, 0, 1): (3266489912, 668265263),
            (3, 0, 0, 0, 2): (2238012525, 1336530526),
        }
        for (seed, phase, rnd, pon, job), want in pins.items():
            key = ops.make_stream_key(seed, phase, rnd, pon=pon,
                                      job=job)
            assert tuple(int(x) for x in key) == want

    def test_jobs_get_distinct_streams(self):
        keys = {
            tuple(ops.make_stream_key(3, 1, 2, pon=1, job=j).tolist())
            for j in range(8)
        }
        assert len(keys) == 8
