"""Multi-round timeline engine vs the per-round reference loop.

The timeline engine (folded and sequential modes) must reproduce the
cycle-by-cycle dict simulator driven one round at a time — same sync
times and same per-round served bits at rtol 1e-6 — including elastic
membership masks and deadline deferral, because both consume the
identical counter-keyed arrival streams.
"""
import numpy as np
import pytest

from repro.core.slicing import ClientProfile
from repro.net import (
    FLRoundWorkload,
    PONConfig,
    SweepCase,
    TimelineSchedule,
    simulate_timeline_per_round,
    simulate_timeline_reference,
    simulate_timeline_sweep,
)

CFG = PONConfig(n_onus=8, line_rate_bps=1e9)


def _clients(ids, seed=0, m_lo=1e5, m_hi=2e6):
    rng = np.random.default_rng(seed)
    return [
        ClientProfile(client_id=int(i),
                      t_ud=float(rng.uniform(0.05, 0.6)), t_dl=0.0,
                      m_ud_bits=float(rng.uniform(m_lo, m_hi)))
        for i in ids
    ]


def _wl(policy, seed=0):
    # fcfs exercises multi-client-per-ONU queues; bs needs ids < n_onus
    ids = range(6) if policy == "bs" else [0, 1, 5, 9, 17, 19]
    return FLRoundWorkload(clients=_clients(ids, seed), model_bits=1.5e6)


def _assert_equal(a, b, rtol=1e-6):
    for ra, rb in zip(a, b):
        assert np.allclose(ra.sync_times, rb.sync_times, rtol=rtol), (
            f"sync {ra.sync_times} vs {rb.sync_times}"
        )
        for x, y in zip(ra.rounds, rb.rounds):
            assert set(x.ul_bits) == set(y.ul_bits)
            for cid, bits in x.ul_bits.items():
                assert bits == pytest.approx(
                    y.ul_bits[cid], rel=rtol, abs=2.0
                ), f"round {x.round_index} client {cid}"
            assert set(x.deferred) == set(y.deferred)
            for cid, bits in x.deferred.items():
                assert bits == pytest.approx(y.deferred[cid], rel=rtol)
            assert x.arrived == y.arrived


class TestParityAgainstReference:
    @pytest.mark.parametrize("policy", ["fcfs", "bs"])
    def test_elastic_membership(self, policy):
        rng = np.random.default_rng(17)
        memb = rng.random((3, 6)) < 0.7
        memb[0] = True
        sched = TimelineSchedule(n_rounds=3, membership=memb)
        cases = [SweepCase(workload=_wl(policy), load=0.5,
                           policy=policy, seed=3),
                 SweepCase(workload=_wl(policy), load=0.8,
                           policy=policy, seed=4)]
        _assert_equal(
            simulate_timeline_sweep(CFG, cases, sched, mode="folded"),
            simulate_timeline_reference(CFG, cases, sched),
        )

    @pytest.mark.parametrize("policy", ["fcfs", "bs"])
    def test_deadline_deferral(self, policy):
        sched = TimelineSchedule(n_rounds=4, deadline_s=0.35)
        cases = [SweepCase(workload=_wl(policy), load=0.6,
                           policy=policy, seed=5)]
        eng = simulate_timeline_sweep(CFG, cases, sched)
        ref = simulate_timeline_reference(CFG, cases, sched)
        assert sum(len(r.deferred) for r in eng[0].rounds) > 0, (
            "deadline chosen to force deferral"
        )
        _assert_equal(eng, ref)

    def test_folded_equals_sequential_exactly(self):
        rng = np.random.default_rng(2)
        memb = rng.random((4, 6)) < 0.6
        memb[0] = True
        sched = TimelineSchedule(n_rounds=4, membership=memb)
        for policy in ("fcfs", "bs"):
            cases = [SweepCase(workload=_wl(policy), load=0.7,
                               policy=policy, seed=1)]
            fold = simulate_timeline_sweep(CFG, cases, sched,
                                           mode="folded")
            seq = simulate_timeline_per_round(CFG, cases, sched)
            _assert_equal(fold, seq, rtol=1e-12)


class TestMembershipDynamics:
    """Property: a client masked out of round r contributes no bits."""

    def test_masked_out_round_contributes_nothing(self):
        memb = np.ones((3, 6), bool)
        memb[1, 2] = False          # client at position 2 sits out r1
        sched = TimelineSchedule(n_rounds=3, membership=memb)
        wl = _wl("fcfs")
        skipped = wl.clients[2].client_id
        res = simulate_timeline_sweep(
            CFG, [SweepCase(workload=wl, load=0.5, policy="fcfs",
                            seed=0)], sched,
        )[0]
        assert res.rounds[1].ul_bits.get(skipped, 0.0) == 0.0
        assert skipped not in res.rounds[1].arrived
        # and participates normally around it
        assert res.rounds[0].ul_bits[skipped] > 0.0
        assert res.rounds[2].ul_bits[skipped] > 0.0

    def test_empty_round_costs_only_aggregation(self):
        memb = np.ones((3, 4), bool)
        memb[1] = False
        sched = TimelineSchedule(n_rounds=3, membership=memb)
        clients = _clients(range(4))
        wl = FLRoundWorkload(clients=clients, model_bits=1e6,
                             t_aggregate=0.25)
        res = simulate_timeline_sweep(
            CFG, [SweepCase(workload=wl, load=0.4, policy="fcfs",
                            seed=0)], sched,
        )[0]
        assert res.rounds[1].sync_time == 0.25
        assert res.rounds[1].ul_bits == {}


class TestDeadlineDynamics:
    """Property: a missed deadline defers — never drops — the
    remaining update bits to the next round."""

    def _run(self, policy="fcfs", deadline=0.3, rounds=5):
        sched = TimelineSchedule(n_rounds=rounds, deadline_s=deadline)
        wl = _wl(policy)
        return wl, simulate_timeline_sweep(
            CFG, [SweepCase(workload=wl, load=0.6, policy=policy,
                            seed=7)], sched,
        )[0]

    def test_deferred_bits_resume_next_round(self):
        wl, res = self._run()
        saw_deferral = False
        for r, nxt in zip(res.rounds, res.rounds[1:]):
            for cid, bits in r.deferred.items():
                saw_deferral = True
                assert bits > 0.0
                # the carrier's next-round service starts from exactly
                # the deferred bits (no re-download, no drop)
                nxt_served = nxt.ul_bits.get(cid, 0.0)
                nxt_left = nxt.deferred.get(cid, 0.0)
                assert nxt_served + nxt_left == pytest.approx(bits)
        assert saw_deferral

    def test_total_bits_conserved_per_upload(self):
        wl, res = self._run()
        m_ud = {c.client_id: c.m_ud_bits for c in wl.clients}
        served = {cid: 0.0 for cid in m_ud}
        uploads_done = {cid: 0 for cid in m_ud}
        for r in res.rounds:
            for cid, bits in r.ul_bits.items():
                served[cid] += bits
            for cid in r.arrived:
                uploads_done[cid] += 1
        for cid in m_ud:
            # every completed upload moved exactly m_ud bits; at most
            # one partial upload is still in flight at the horizon
            leftover = served[cid] - uploads_done[cid] * m_ud[cid]
            assert -2.0 <= leftover <= m_ud[cid]

    def test_sync_capped_by_deadline(self):
        _, res = self._run(deadline=0.3)
        for r in res.rounds:
            if r.deferred:
                assert r.sync_time == pytest.approx(0.3)

    def test_folded_mode_rejects_deadlines(self):
        sched = TimelineSchedule(n_rounds=2, deadline_s=0.5)
        with pytest.raises(ValueError, match="folded"):
            simulate_timeline_sweep(
                CFG,
                [SweepCase(workload=_wl("fcfs"), load=0.5,
                           policy="fcfs", seed=0)],
                sched, mode="folded",
            )


class TestScheduleValidation:
    def test_membership_width_checked(self):
        sched = TimelineSchedule(n_rounds=2,
                                 membership=np.ones((2, 3), bool))
        with pytest.raises(ValueError, match="membership"):
            simulate_timeline_sweep(
                CFG,
                [SweepCase(workload=_wl("fcfs"), load=0.5,
                           policy="fcfs", seed=0)],
                sched,
            )

    def test_membership_shape_checked(self):
        with pytest.raises(ValueError, match="membership"):
            TimelineSchedule(n_rounds=3,
                             membership=np.ones((2, 4), bool))

    def test_injected_arrivals_rejected(self):
        case = SweepCase(workload=_wl("fcfs"), load=0.5, policy="fcfs",
                         seed=0, dl_arrivals=np.zeros((10, 8)))
        with pytest.raises(ValueError, match="counter streams"):
            simulate_timeline_sweep(
                CFG, [case], TimelineSchedule(n_rounds=1),
            )

    def test_per_round_m_ud_override(self):
        sched = TimelineSchedule(
            n_rounds=2, m_ud_bits=np.array([4e5, 8e5])
        )
        res = simulate_timeline_sweep(
            CFG,
            [SweepCase(workload=_wl("fcfs"), load=0.4, policy="fcfs",
                       seed=0)],
            sched,
        )[0]
        for r, expect in zip(res.rounds, (4e5, 8e5)):
            for bits in r.ul_bits.values():
                assert bits == pytest.approx(expect)


class TestCoSimBackend:
    def _cosim(self):
        pytest.importorskip("jax")
        import jax
        from repro.data import build_federated_cnn_clients
        from repro.fl import CPSServer, SelectionConfig
        from repro.fl.client import LocalTrainConfig
        from repro.fl.simulation import CoSimConfig, FLNetworkCoSim
        from repro.models import cnn

        clients, _ = build_federated_cnn_clients(
            n_clients=4, samples_per_client=16, loss_fn=cnn.loss_fn,
            train_cfg=LocalTrainConfig(lr=0.05, batch_size=8,
                                       local_epochs=1),
            seed=0,
        )
        server = CPSServer(
            global_params=cnn.init_params(jax.random.PRNGKey(0)),
            clients=clients,
            selection=SelectionConfig(strategy="all"),
            seed=0,
        )
        cfg = CoSimConfig(
            policy="bs", total_load=0.5, model_bits=2e6,
            upload_bits=2e6, timing_seeds=2,
            pon=PONConfig(n_onus=8, line_rate_bps=1e9),
        )
        return FLNetworkCoSim(server, cfg)

    def test_timeline_backend_is_default_and_complete(self):
        sim = self._cosim()
        res = sim.run(n_rounds=3)
        assert len(res.rounds) == 3
        syncs = [r["sync_time_s"] for r in res.rounds]
        assert all(s > 0 for s in syncs)
        assert res.total_time_s == pytest.approx(sum(syncs))
        assert res.sync_time_s == pytest.approx(syncs[-1])

    def test_per_round_backend_still_works(self):
        sim = self._cosim()
        res = sim.run(n_rounds=2, backend="per_round")
        assert len(res.rounds) == 2
        assert all(r["sync_time_s"] > 0 for r in res.rounds)

    def test_unknown_backend_raises(self):
        sim = self._cosim()
        with pytest.raises(ValueError, match="unknown backend"):
            sim.run(n_rounds=1, backend="magic")
