"""Sweeps for the XLA flash attention (the production attn_impl)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.attention.ref import attention_ref
from repro.models.flash_xla import flash_attention_xla

KEY = jax.random.PRNGKey(3)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,S,H,K,D,causal,window,bq,bk",
    [
        (2, 256, 4, 2, 64, True, None, 64, 64),    # GQA causal
        (1, 333, 4, 1, 32, True, None, 128, 64),   # MQA ragged seq
        (2, 256, 4, 2, 64, True, 64, 64, 64),      # sliding window
        (1, 128, 8, 8, 64, False, None, 32, 128),  # MHA bidirectional
        (1, 96, 2, 2, 128, True, 8, 32, 32),       # tiny window
    ],
)
def test_fwd_matches_reference(B, S, H, K, D, causal, window, bq, bk, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, D), dtype)
    k = jax.random.normal(ks[1], (B, S, K, D), dtype)
    v = jax.random.normal(ks[2], (B, S, K, D), dtype)
    out = flash_attention_xla(q, k, v, causal, window, bq, bk)
    ref = attention_ref(q, k, v, causal, window)
    tol = dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else dict(
        atol=2e-5, rtol=2e-5
    )
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **tol
    )


@pytest.mark.parametrize(
    "S,window,bq,bk", [(128, None, 32, 32), (160, 48, 64, 32)]
)
def test_grads_match_reference(S, window, bq, bk):
    ks = jax.random.split(KEY, 3)
    B, H, K, D = 1, 4, 2, 32
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, K, D))
    v = jax.random.normal(ks[2], (B, S, K, D))

    def f1(q, k, v):
        return jnp.sum(jnp.sin(flash_attention_xla(q, k, v, True, window,
                                                   bq, bk)))

    def f2(q, k, v):
        return jnp.sum(jnp.sin(attention_ref(q, k, v, True, window)))

    g1 = jax.grad(f1, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f2, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-5, rtol=5e-5)


def test_model_level_equivalence_chunked_vs_reference():
    """Full LM forward: attn_impl=chunked == attn_impl=reference."""
    from repro.configs import get_config
    from repro.models import lm

    cfg_ref = get_config("gemma3-12b", smoke=True)
    cfg_chk = cfg_ref.replace(attn_impl="chunked")
    params = lm.init_params(KEY, cfg_ref)
    tokens = jax.random.randint(jax.random.PRNGKey(5), (2, 24), 0,
                                cfg_ref.vocab_size)
    lr, _ = lm.forward_train(params, cfg_ref, tokens)
    lc, _ = lm.forward_train(params, cfg_chk, tokens)
    np.testing.assert_allclose(np.asarray(lr), np.asarray(lc),
                               atol=1e-4, rtol=1e-4)


def test_moe_grouping_equivalence():
    """Grouped dispatch == ungrouped when capacity is dropless."""
    import dataclasses

    from repro.configs import get_config
    from repro.models import lm

    base = get_config("mixtral-8x22b", smoke=True)
    cfg_1 = base.replace(moe=dataclasses.replace(base.moe, group_tokens=10**9))
    cfg_g = base.replace(moe=dataclasses.replace(base.moe, group_tokens=8))
    params = lm.init_params(KEY, cfg_1)
    tokens = jax.random.randint(jax.random.PRNGKey(6), (2, 16), 0,
                                base.vocab_size)
    l1, _ = lm.forward_train(params, cfg_1, tokens)
    lg, _ = lm.forward_train(params, cfg_g, tokens)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(lg),
                               atol=1e-4, rtol=1e-4)
