"""Fault injection: stream determinism, engine/oracle parity, recovery.

Four contracts are pinned here:

* the counter-based fault streams (``repro.faults.streams``) are
  chunk-invariant, O(1)-seekable and bit-stable (pinned fingerprints);
* faults off — ``faults=None`` and a trivial ``FaultSchedule`` — is
  bitwise identical across every timeline mode, including the Fig. 2b
  operating-point sync pin;
* with faults on, the batched engine matches the cycle-level reference
  oracle at rtol 1e-6 across dropout/outage/loss x {fcfs, bs} x
  {defer, drop, partial, async} x multi-PON, *including* the fault
  bookkeeping (failed/lost/retry_at/gave_up/quorum verdicts);
* the recovery machinery behaves: retry-with-backoff suppresses fresh
  membership entry while backing off (the satellite-2 invariant),
  quorum aggregation extends-then-degrades, and a killed
  ``launch/train`` co-sim resumes to bitwise-identical final params.
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core.slicing import ClientProfile
from repro.faults import FaultSchedule, RetryPolicy
from repro.faults.streams import (
    FAULT_DROPOUT,
    FAULT_LOSS,
    FAULT_OUTAGE,
    fault_fingerprint,
    fault_key,
    fault_uniforms,
)
from repro.net import (
    FLRoundWorkload,
    MultiPonTopology,
    PONConfig,
    SweepCase,
    TimelineSchedule,
    simulate_timeline_reference,
    simulate_timeline_sweep,
)
from repro.net.timeline import _RetryEntry, _round_setup

CFG = PONConfig(n_onus=8, line_rate_bps=1e9)

# rates chosen so every fault class fires within a handful of rounds
FAULTS = FaultSchedule(seed=3, dropout_rate=0.25, loss_rate=0.15,
                       outage_rate=0.5, outage_duration_s=0.1,
                       outage_start_max_s=0.5)


def _clients(ids, seed=0, m_lo=1e5, m_hi=2e6):
    rng = np.random.default_rng(seed)
    return [
        ClientProfile(client_id=int(i),
                      t_ud=float(rng.uniform(0.05, 0.6)), t_dl=0.0,
                      m_ud_bits=float(rng.uniform(m_lo, m_hi)))
        for i in ids
    ]


def _wl(policy, seed=0):
    ids = range(6) if policy == "bs" else [0, 1, 5, 9, 17, 19]
    return FLRoundWorkload(clients=_clients(ids, seed), model_bits=1.5e6)


def _assert_equal(a, b, rtol=1e-6):
    for ra, rb in zip(a, b):
        assert np.allclose(ra.sync_times, rb.sync_times, rtol=rtol), (
            f"sync {ra.sync_times} vs {rb.sync_times}"
        )
        for x, y in zip(ra.rounds, rb.rounds):
            assert x.arrived == y.arrived
            assert x.staleness == y.staleness
            assert sorted(x.lost) == sorted(y.lost)
            assert sorted(x.gave_up) == sorted(y.gave_up)
            assert x.retry_at == y.retry_at
            assert x.quorum_met == y.quorum_met
            assert x.deadline_extensions == y.deadline_extensions
            assert set(x.failed) == set(y.failed)
            for cid, v in x.failed.items():
                assert v == pytest.approx(y.failed[cid], rel=rtol,
                                          abs=2.0)
            for name in ("ul_bits", "deferred", "dropped", "partial"):
                xd, yd = getattr(x, name), getattr(y, name)
                assert set(xd) == set(yd), (x.round_index, name)
                for cid, v in xd.items():
                    assert v == pytest.approx(yd[cid], rel=rtol, abs=2.0)


# ---------------------------------------------------------------------------
# counter-based streams
# ---------------------------------------------------------------------------


class TestFaultStreams:
    CLASSES = (FAULT_DROPOUT, FAULT_OUTAGE, FAULT_LOSS)

    def test_chunk_invariance(self):
        """One batched draw == per-entity draws, bit for bit."""
        ids = np.arange(24)
        for cls in self.CLASSES:
            b0, b1 = fault_uniforms(3, cls, 2, ids, case_seed=5)
            for i in ids:
                s0, s1 = fault_uniforms(3, cls, 2, int(i), case_seed=5)
                assert s0 == b0[i] and s1 == b1[i]

    def test_seekable_any_order(self):
        """Round r's draws don't depend on which rounds were drawn
        before (no sequential RNG state)."""
        fwd = [fault_uniforms(1, FAULT_DROPOUT, r, 7)[0]
               for r in range(6)]
        rev = [fault_uniforms(1, FAULT_DROPOUT, r, 7)[0]
               for r in reversed(range(6))]
        assert fwd == rev[::-1]

    def test_pinned_fingerprints(self):
        """Exact stream bits — any change to keying or the threefry
        core is a determinism break, not a refactor."""
        pins = {
            (FAULT_DROPOUT, 0, 0): 0x4B14B5901A556C85,
            (FAULT_DROPOUT, 5, 7): 0x5379E8E3DA420974,
            (FAULT_OUTAGE, 0, 0): 0x770188B2C65163C8,
            (FAULT_OUTAGE, 5, 7): 0x4C4DA1B9F892DE6E,
            (FAULT_LOSS, 0, 0): 0x94778675CC2AA9A1,
            (FAULT_LOSS, 5, 7): 0xC0FAF1B1D2B640CD,
        }
        for (cls, r, case), want in pins.items():
            assert fault_fingerprint(3, cls, r, 16, case_seed=case) == want

    def test_streams_distinct_per_class_and_case(self):
        keys = {fault_key(3, cls, case)
                for cls in self.CLASSES for case in (0, 1, 7)}
        assert len(keys) == len(self.CLASSES) * 3

    def test_uniforms_open_interval(self):
        u0, u1 = fault_uniforms(0, FAULT_LOSS, 0, np.arange(4096))
        for u in (u0, u1):
            assert np.all(u > 0.0) and np.all(u < 1.0)


class TestFaultScheduleModel:
    def test_rate_zero_never_fires_rate_one_always(self):
        ids = list(range(32))
        never = FaultSchedule(seed=0)
        assert never.dropouts(0, ids) == {}
        assert never.losses(0, ids) == frozenset()
        assert np.all(np.isinf(never.outage_windows(0, 4)))
        always = FaultSchedule(seed=0, dropout_rate=1.0, loss_rate=1.0,
                               outage_rate=1.0)
        assert set(always.dropouts(0, ids)) == set(ids)
        assert always.losses(0, ids) == frozenset(ids)
        assert np.all(np.isfinite(always.outage_windows(0, 4)))

    def test_trivial_and_couples_rounds(self):
        assert FaultSchedule().trivial
        assert not FaultSchedule().couples_rounds
        assert not FaultSchedule(outage_rate=0.5).trivial
        assert not FaultSchedule(outage_rate=0.5).couples_rounds
        assert FaultSchedule(dropout_rate=0.1).couples_rounds
        assert FaultSchedule(loss_rate=0.1).couples_rounds

    def test_outage_window_shape(self):
        w = FaultSchedule(seed=1, outage_rate=1.0, outage_duration_s=0.2,
                          outage_start_max_s=0.5).outage_windows(3, 5)
        assert w.shape == (5, 2)
        assert np.all(w[:, 0] >= 0.0) and np.all(w[:, 0] <= 0.5)
        assert np.allclose(w[:, 1] - w[:, 0], 0.2)

    def test_validation(self):
        with pytest.raises(ValueError):
            FaultSchedule(dropout_rate=1.5)
        with pytest.raises(ValueError):
            FaultSchedule(outage_duration_s=0.0)
        with pytest.raises(ValueError):
            FaultSchedule(outage_start_max_s=-1.0)

    def test_retry_policy(self):
        p = RetryPolicy()
        assert [p.delay_rounds(a) for a in (1, 2, 3)] == [1, 2, 4]
        assert RetryPolicy(base_delay_rounds=2, backoff=1.0
                           ).delay_rounds(3) == 2
        with pytest.raises(ValueError):
            RetryPolicy(base_delay_rounds=0)
        with pytest.raises(ValueError):
            RetryPolicy(backoff=0.5)
        with pytest.raises(ValueError):
            RetryPolicy(max_retries=-1)


# ---------------------------------------------------------------------------
# faults off == no faults, bitwise
# ---------------------------------------------------------------------------


class TestFaultsOffBitwise:
    SCHEDS = (
        dict(n_rounds=3),
        dict(n_rounds=3, deadline_s=0.35, deadline_policy="defer"),
        dict(n_rounds=3, deadline_s=0.35, deadline_policy="drop"),
        dict(n_rounds=3, deadline_s=0.35, deadline_policy="partial"),
        dict(n_rounds=3, buffer_k=3),
    )

    def test_trivial_schedule_bitwise_identical(self):
        cases = [SweepCase(workload=_wl("fcfs"), load=0.6,
                           policy="fcfs", seed=5)]
        for kw in self.SCHEDS:
            off = simulate_timeline_sweep(
                CFG, cases, TimelineSchedule(**kw))
            triv = simulate_timeline_sweep(
                CFG, cases,
                TimelineSchedule(faults=FaultSchedule(), **kw))
            for a, b in zip(off, triv):
                assert np.array_equal(a.sync_times, b.sync_times)
                for x, y in zip(a.rounds, b.rounds):
                    assert x.ul_bits == y.ul_bits
                    assert x.arrived == y.arrived
                    assert x.failed == {} and y.failed == {}

    def test_operating_point_pin_with_trivial_faults(self):
        """The Fig. 2b 0.8-load pin survives a wired-but-all-zero
        FaultSchedule bit for bit."""
        rng = np.random.default_rng(42)
        t_uds = rng.uniform(1.0, 5.0, 128)
        clients = [
            ClientProfile(client_id=i, t_ud=float(t_uds[i]), t_dl=0.0,
                          m_ud_bits=26.416e6)
            for i in range(12)
        ]
        wl = FLRoundWorkload(clients=clients, model_bits=26.416e6)
        case = SweepCase(workload=wl, load=0.8, policy="fcfs", seed=1)
        for sched in (
            TimelineSchedule(n_rounds=1, faults=FaultSchedule()),
            TimelineSchedule(n_rounds=1, deadline_s=30.0,
                             deadline_policy="drop",
                             faults=FaultSchedule(seed=9)),
        ):
            res = simulate_timeline_sweep(
                PONConfig(n_onus=128), [case], sched)[0]
            assert res.rounds[0].sync_time == pytest.approx(
                5.058100000000024, abs=1e-9
            )


# ---------------------------------------------------------------------------
# fault-enabled engine vs cycle-level reference oracle
# ---------------------------------------------------------------------------


class TestFaultParityVsOracle:
    @pytest.mark.parametrize("policy", ["fcfs", "bs"])
    @pytest.mark.parametrize("sched_kw", [
        dict(n_rounds=5, deadline_s=0.4, deadline_policy="defer"),
        dict(n_rounds=5, deadline_s=0.35, deadline_policy="drop"),
        dict(n_rounds=5, deadline_s=0.35, deadline_policy="partial"),
        dict(n_rounds=5, buffer_k=3),
    ], ids=["defer", "drop", "partial", "async"])
    def test_modes(self, policy, sched_kw):
        sched = TimelineSchedule(faults=FAULTS, **sched_kw)
        cases = [SweepCase(workload=_wl(policy), load=0.6,
                           policy=policy, seed=5)]
        eng = simulate_timeline_sweep(CFG, cases, sched)
        ref = simulate_timeline_reference(CFG, cases, sched)
        assert sum(len(r.failed) + len(r.lost)
                   for r in eng[0].rounds) > 0, (
            "rates chosen to actually fire"
        )
        _assert_equal(eng, ref)

    @pytest.mark.parametrize("policy", ["fcfs", "bs"])
    def test_multi_pon(self, policy):
        topo = MultiPonTopology(n_pons=2, cps_rate_bps=1.8e9)
        ids = range(12) if policy == "bs" else [0, 1, 5, 9, 12, 14]
        wl = FLRoundWorkload(clients=_clients(ids), model_bits=1.5e6)
        faults = FaultSchedule(seed=7, dropout_rate=0.25, loss_rate=0.15,
                               outage_rate=0.5, outage_duration_s=0.1,
                               outage_start_max_s=0.5)
        cases = [SweepCase(workload=wl, load=0.4, policy=policy,
                           seed=5, topology=topo)]
        for sched in (
            TimelineSchedule(n_rounds=4, deadline_s=0.4, faults=faults),
            TimelineSchedule(n_rounds=4, buffer_k=3, faults=faults),
        ):
            _assert_equal(
                simulate_timeline_sweep(CFG, cases, sched),
                simulate_timeline_reference(CFG, cases, sched),
            )

    def test_outage_only_folded_matches_sequential(self):
        """Outage masks capacity but cancels nothing, so outage-only
        schedules stay fold-legal — all three drivers agree."""
        faults = FaultSchedule(seed=3, outage_rate=1.0,
                               outage_duration_s=0.2,
                               outage_start_max_s=0.0)
        sched = TimelineSchedule(n_rounds=4, faults=faults)
        cases = [SweepCase(workload=_wl("fcfs"), load=0.6,
                           policy="fcfs", seed=5)]
        base = simulate_timeline_sweep(
            CFG, cases, TimelineSchedule(n_rounds=4))
        fold = simulate_timeline_sweep(CFG, cases, sched, mode="folded")
        seq = simulate_timeline_sweep(CFG, cases, sched,
                                      mode="sequential")
        ref = simulate_timeline_reference(CFG, cases, sched)
        assert not np.array_equal(base[0].sync_times,
                                  fold[0].sync_times), (
            "outage rate chosen to actually slow a round"
        )
        _assert_equal(fold, seq, rtol=1e-12)
        _assert_equal(fold, ref)

    def test_coupling_faults_reject_folded(self):
        sched = TimelineSchedule(n_rounds=2, faults=FAULTS)
        cases = [SweepCase(workload=_wl("fcfs"), load=0.6,
                           policy="fcfs", seed=5)]
        with pytest.raises(ValueError, match="folded"):
            simulate_timeline_sweep(CFG, cases, sched, mode="folded")


# ---------------------------------------------------------------------------
# retry-with-backoff rescheduling (satellite 2 regression included)
# ---------------------------------------------------------------------------


class TestRetrySemantics:
    def _run(self, retry=None, n_rounds=6, faults=None):
        sched = TimelineSchedule(
            n_rounds=n_rounds, deadline_s=0.4, deadline_policy="drop",
            faults=faults or FaultSchedule(seed=3, dropout_rate=0.35),
            retry=retry,
        )
        cases = [SweepCase(workload=_wl("fcfs"), load=0.6,
                           policy="fcfs", seed=5)]
        return simulate_timeline_sweep(CFG, cases, sched)[0]

    def test_retry_due_rounds_follow_backoff(self):
        res = self._run()
        delays = RetryPolicy()
        booked = 0
        for r in res.rounds:
            for cid, due in r.retry_at.items():
                booked += 1
                gaps = [r.round_index + delays.delay_rounds(a)
                        for a in (1, 2, 3)]
                assert due in gaps, (cid, due, gaps)
        assert booked > 0, "dropout rate chosen to book retries"

    def test_backoff_suppresses_membership_reentry(self):
        """Satellite-2 invariant: while a client is backing off, the
        (implicit all-ones) membership mask must NOT re-admit it as a
        fresh member — it is absent from every round before its due
        round, then re-enters exactly once."""
        res = self._run(retry=RetryPolicy(base_delay_rounds=2))
        checked = 0
        for r in res.rounds:
            for cid, due in r.retry_at.items():
                for mid in res.rounds[r.round_index + 1:due]:
                    checked += 1
                    assert cid not in mid.ul_bits, (
                        f"client {cid} revived at round "
                        f"{mid.round_index} while backing off until "
                        f"{due}"
                    )
                if due < len(res.rounds):
                    assert cid in res.rounds[due].ul_bits
        assert checked > 0, "need a backoff window inside the horizon"

    def test_retry_resends_full_payload(self):
        """The retry re-sends the failure round's pre-truncation
        pending bits — under the drop policy every entry is full, so
        a completed retry serves the whole payload even though the
        failure round only wasted a fragment (``rnd.failed``)."""
        wl = _wl("fcfs")
        m_ud = {c.client_id: c.m_ud_bits for c in wl.clients}
        res = self._run()
        completed = 0
        for r in res.rounds:
            for cid, due in r.retry_at.items():
                assert r.failed[cid] <= m_ud[cid] + 2.0
                if due < len(res.rounds):
                    rr = res.rounds[due]
                    if cid in rr.arrived:
                        completed += 1
                        assert rr.ul_bits[cid] == pytest.approx(
                            m_ud[cid], rel=1e-9, abs=2.0)
        assert completed > 0, "need at least one completed retry"

    def test_max_retries_zero_gives_up_immediately(self):
        res = self._run(retry=RetryPolicy(max_retries=0))
        gave = sum(len(r.gave_up) for r in res.rounds)
        assert gave > 0
        assert all(r.retry_at == {} for r in res.rounds)

    def test_carry_and_retry_overlap_is_a_hard_error(self):
        """A cid in both the deferred carry and the retry table means
        the bookkeeping desynced; _round_setup must refuse."""
        case = SweepCase(workload=_wl("fcfs"), load=0.6,
                         policy="fcfs", seed=5)
        sched = TimelineSchedule(n_rounds=2)
        with pytest.raises(RuntimeError, match="both a deferred"):
            _round_setup(case, sched, 1, {1: 1000.0},
                         {1: _RetryEntry(1, 500.0, 1)})


# ---------------------------------------------------------------------------
# quorum aggregation: timeline extension + fl/dist commit gates
# ---------------------------------------------------------------------------


class TestQuorumTimeline:
    def _sched(self, **kw):
        base = dict(n_rounds=5, deadline_s=0.12,
                    deadline_policy="drop",
                    faults=FaultSchedule(seed=3, dropout_rate=0.25),
                    quorum_frac=0.75)
        base.update(kw)
        return TimelineSchedule(**base)

    def test_engine_matches_reference(self):
        cases = [SweepCase(workload=_wl("fcfs"), load=0.6,
                           policy="fcfs", seed=5)]
        sched = self._sched()
        eng = simulate_timeline_sweep(CFG, cases, sched)
        ref = simulate_timeline_reference(CFG, cases, sched)
        assert sum(r.deadline_extensions for r in eng[0].rounds) > 0, (
            "deadline chosen tight enough to force extensions"
        )
        _assert_equal(eng, ref)

    def test_extension_doubles_until_met_or_degrades(self):
        res = simulate_timeline_sweep(
            CFG, [SweepCase(workload=_wl("fcfs"), load=0.6,
                            policy="fcfs", seed=5)],
            self._sched(),
        )[0]
        for r in res.rounds:
            assert r.quorum_met is not None
            assert 0 <= r.deadline_extensions <= 2
            if r.quorum_met:
                # enough un-faulted arrivals relative to what entered
                assert len(r.arrived) >= 1
            else:
                assert r.deadline_extensions == 2, (
                    "an unmet round must have used every extension"
                )

    def test_validation(self):
        with pytest.raises(ValueError, match="quorum_frac"):
            TimelineSchedule(n_rounds=1, deadline_s=1.0,
                             quorum_frac=1.5)
        with pytest.raises(ValueError, match="deadline"):
            TimelineSchedule(n_rounds=1, quorum_frac=0.5)
        with pytest.raises(ValueError, match="quorum"):
            TimelineSchedule(n_rounds=1, buffer_k=2, quorum_frac=0.5)
        with pytest.raises(ValueError):
            TimelineSchedule(n_rounds=1, deadline_s=1.0,
                             quorum_frac=0.5, quorum_max_extends=-1)


class TestQuorumAggregation:
    def test_threshold(self):
        from repro.fl.aggregation import quorum_threshold

        assert quorum_threshold(8, 0.5) == 4
        assert quorum_threshold(8, 0.51) == 5
        assert quorum_threshold(8, 1.0) == 8
        assert quorum_threshold(0, 0.5) == 1   # never commit on zero
        with pytest.raises(ValueError):
            quorum_threshold(8, 0.0)
        with pytest.raises(ValueError):
            quorum_threshold(-1, 0.5)

    def test_commit_degrades_below_quorum(self):
        from repro.fl.aggregation import quorum_commit

        g = {"w": np.ones(3, np.float32)}
        deltas = [{"w": np.full(3, 0.5, np.float32)}]
        out, ok = quorum_commit(g, deltas, [1.0], n_expected=4,
                                quorum_frac=0.5)
        assert not ok and out is g     # untouched, same object
        out, ok = quorum_commit(g, deltas * 2, [1.0, 1.0],
                                n_expected=4, quorum_frac=0.5)
        assert ok
        assert np.allclose(out["w"], 1.5)

    def test_server_apply_updates_quorum(self):
        from repro.fl.server import CPSServer, PendingUpdate

        g = {"w": np.zeros(2, np.float32)}
        srv = CPSServer(global_params=g, clients=[])
        upd = PendingUpdate(client_id=0,
                            delta={"w": np.ones(2, np.float32)},
                            weight=1.0, loss=0.1, bits=8.0)
        log = srv.apply_updates([(upd, 0, 1.0)], n_expected=3,
                                quorum_frac=0.5)   # need ceil(1.5) = 2
        assert log.quorum_met is False
        assert np.allclose(srv.global_params["w"], 0.0)
        log = srv.apply_updates([(upd, 0, 1.0), (upd, 0, 1.0)],
                                n_expected=3, quorum_frac=0.5)
        assert log.quorum_met is True
        assert not np.allclose(srv.global_params["w"], 0.0)
        with pytest.raises(ValueError, match="n_expected"):
            srv.apply_updates([(upd, 0, 1.0)], quorum_frac=0.5)

    def test_fedbuff_pods_quorum_gate(self):
        import jax.numpy as jnp

        from repro.dist.fedops import fedbuff_pods

        n = 2
        pending = {"w": jnp.ones((n, 3), jnp.float32)}
        g = {"w": jnp.zeros((n, 3), jnp.float32)}
        weights = jnp.ones(n)
        stale = jnp.zeros(n)
        one = jnp.array([True, False])
        met = fedbuff_pods(pending, g, weights, one, stale,
                           quorum_frac=0.5)
        assert float(jnp.abs(met["w"]).sum()) > 0.0
        degraded = fedbuff_pods(pending, g, weights, one, stale,
                                quorum_frac=1.0)
        assert float(jnp.abs(degraded["w"]).sum()) == 0.0
        # n_expected overrides the pod count
        degraded2 = fedbuff_pods(pending, g, weights, one, stale,
                                 quorum_frac=0.5, n_expected=4)
        assert float(jnp.abs(degraded2["w"]).sum()) == 0.0


# ---------------------------------------------------------------------------
# satellite 1: per-baseline-file gate coverage (benchmarks/compare.py)
# ---------------------------------------------------------------------------


class TestCompareCoverage:
    def _mod(self):
        sys.path.insert(0, os.path.dirname(
            os.path.dirname(os.path.abspath(__file__))))
        from benchmarks import compare
        return compare

    def test_uncovered_file_flagged_with_its_keys(self):
        compare = self._mod()
        errs = compare.check_baseline_coverage(
            {"BENCH_a.json": {"a.rounds_per_sec": 1.0},
             "BENCH_b.json": {"b.rounds_per_sec": 2.0}},
            {"a.rounds_per_sec": 1.0},
        )
        assert len(errs) == 1
        assert "BENCH_b.json" in errs[0]
        assert "b.rounds_per_sec" in errs[0]

    def test_covered_and_empty_files_pass(self):
        compare = self._mod()
        assert compare.check_baseline_coverage(
            {"BENCH_a.json": {"a.rounds_per_sec": 1.0},
             "BENCH_empty.json": {}},
            {"a.rounds_per_sec": 1.0},
        ) == []

    def test_main_exits_2_on_uncovered_baseline(self, tmp_path):
        compare = self._mod()
        cur = tmp_path / "cur.json"
        base_ok = tmp_path / "base_ok.json"
        base_orphan = tmp_path / "base_orphan.json"
        payload = {"benchmark": "fault_injection_grid", "cells": [
            {"mode": "sync", "dropout_rate": 0.2, "outage_rate": 0.5,
             "rounds_per_sec": 2.0},
        ]}
        cur.write_text(json.dumps(payload))
        base_ok.write_text(json.dumps(payload))
        orphan = {"rows": [{"name": "phantom",
                            "derived": "rounds_per_sec=9.9"}]}
        base_orphan.write_text(json.dumps(orphan))
        assert compare.main(["--current", str(cur),
                             "--baseline", str(base_ok)]) == 0
        assert compare.main(["--current", str(cur),
                             "--baseline", str(base_ok),
                             str(base_orphan)]) == 2

    def test_fault_grid_payload_metrics(self):
        compare = self._mod()
        payload = {"benchmark": "fault_injection_grid", "cells": [
            {"mode": "quorum", "dropout_rate": 0.2, "outage_rate": 0.5,
             "rounds_per_sec": 1.25},
        ]}
        assert compare.extract_metrics(payload) == {
            "fault_grid_quorum_d20_o50.rounds_per_sec": 1.25
        }


# ---------------------------------------------------------------------------
# crash/resume of a long co-sim (launch/train --resume)
# ---------------------------------------------------------------------------


_RESUME_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    import shutil
    import numpy as np, jax
    from repro.launch.train import train

    base = os.environ["RESUME_TMP"]
    d1, d2 = os.path.join(base, "full"), os.path.join(base, "resumed")
    kw = dict(arch="olmo-1b", smoke=True, steps_per_round=2, rounds=3,
              n_pods=2, global_batch=4, seq_len=16, deadline_s=2.0,
              deadline_policy="defer", dropout_rate=0.4, loss_rate=0.2,
              outage_rate=0.5, fault_seed=3, quorum=0.5)
    sa, _ = train(ckpt_dir=d1, resume=False, **kw)
    # emulate a mid-timeline crash after round 2: only that round's
    # checkpoint survives into a fresh directory
    os.makedirs(d2)
    shutil.copy(os.path.join(d1, "step_2.ckpt"), d2)
    sb, _ = train(ckpt_dir=d2, resume=True, **kw)
    la, lb = jax.tree.leaves(sa), jax.tree.leaves(sb)
    assert len(la) == len(lb)
    assert all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(la, lb)), "resume diverged from the "
    print("RESUME_BITWISE_OK")
""")


@pytest.mark.slow
class TestCrashResume:
    def test_resume_reproduces_uninterrupted_run(self, tmp_path):
        """Kill-after-round-2 + ``--resume`` must land on bitwise the
        same final params as the uninterrupted run (faults + quorum
        active, coupled async state checkpointed alongside train
        state)."""
        env = dict(os.environ)
        env["RESUME_TMP"] = str(tmp_path)
        env["PYTHONPATH"] = os.pathsep.join(filter(None, [
            os.path.join(os.path.dirname(os.path.dirname(
                os.path.abspath(__file__))), "src"),
            env.get("PYTHONPATH", ""),
        ]))
        out = subprocess.run(
            [sys.executable, "-c", _RESUME_SCRIPT],
            capture_output=True, text=True, env=env, timeout=900,
        )
        assert out.returncode == 0, out.stderr[-4000:]
        assert "RESUME_BITWISE_OK" in out.stdout
