"""Distribution tests on a small host mesh (subprocess isolation for the
device-count env var, since the main test process must keep 1 device)."""
import os
import subprocess
import sys
import textwrap

import pytest

from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.dist.sharding import param_spec


class TestShardingRules:
    MESH = None

    @classmethod
    def setup_class(cls):
        # an abstract mesh over 1 device would make every axis size 1;
        # use jax's AbstractMesh for pure spec logic
        from jax.sharding import AbstractMesh

        try:
            cls.MESH = AbstractMesh((16, 16), ("data", "model"))
        except TypeError:  # jax<=0.4.x: (name, size) pair signature
            cls.MESH = AbstractMesh((("data", 16), ("model", 16)))

    def spec(self, names, shape, cfg, **kw):
        return param_spec(names, shape, cfg, self.MESH, **kw)

    def test_column_row_rules(self):
        cfg = get_config("llama3-8b")
        assert self.spec(["units", "b0", "mixer", "wq"],
                         (32, 4096, 4096), cfg) == P(None, None, "model")
        assert self.spec(["units", "b0", "mixer", "wo"],
                         (32, 4096, 4096), cfg) == P(None, "model", None)
        assert self.spec(["units", "b0", "mlp", "w_down"],
                         (32, 14336, 4096), cfg) == P(None, "model", None)

    def test_embedding_vocab_fallback(self):
        # mamba2 vocab 50280 is NOT divisible by 16 -> shard d_model instead
        cfg = get_config("mamba2-780m")
        assert self.spec(["embed"], (50280, 1536), cfg) == P(None, "model")
        cfg2 = get_config("llama3-8b")
        assert self.spec(["embed"], (128256, 4096), cfg2) == P("model", None)

    def test_moe_expert_parallel_vs_fallback(self):
        arctic = get_config("arctic-480b")     # 128 experts: EP over model
        s = self.spec(["units", "b0", "moe", "w_gate"],
                      (1, 128, 7168, 4864), arctic)
        assert s[1] == "model"
        mixtral = get_config("mixtral-8x22b")  # 8 experts < 16: tensor shard
        s2 = self.spec(["units", "b0", "moe", "w_gate"],
                       (1, 8, 6144, 16384), mixtral)
        assert s2[1] is None and s2[3] == "model"

    def test_fsdp_adds_data_axis(self):
        cfg = get_config("arctic-480b")        # fsdp=True
        s = self.spec(["units", "b0", "mixer", "wq"],
                      (1, 7168, 7168), cfg)
        assert s == P(None, "data", "model")

    def test_norms_replicated(self):
        cfg = get_config("llama3-8b")
        assert self.spec(["units", "b0", "mix_norm", "scale"],
                         (32, 4096), cfg) == P(None)


@pytest.mark.slow
class TestSmallMeshEndToEnd:
    """Run a tiny federated train + round step on 8 fake devices."""

    def test_fed_steps_run(self, tmp_path):
        script = textwrap.dedent("""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
            import jax, jax.numpy as jnp
            from repro.configs import get_config
            from repro.dist import stepfns
            from repro.optim.optimizers import OptimizerConfig
            from repro.launch.mesh import make_host_mesh, batch_axes
            from jax.sharding import NamedSharding, PartitionSpec as P

            cfg = get_config("olmo-1b", smoke=True).replace(grad_accum=2)
            opt = OptimizerConfig(name="adamw", lr=1e-2)
            mesh = make_host_mesh(model_parallel=2, pods=2)  # (2,2,2)
            n_pods = 2
            with mesh:
                state = stepfns.init_fed_state(
                    jax.random.PRNGKey(0), cfg, opt, n_pods)
                step = stepfns.make_fed_train_step(cfg, opt)
                B, S = 8, 16
                tokens = jax.random.randint(
                    jax.random.PRNGKey(1), (n_pods, B // n_pods, S),
                    0, cfg.vocab_size)
                batch = {"tokens": tokens, "labels": tokens}
                state2, metrics = jax.jit(step)(state, batch)
                loss = float(metrics["loss"].mean())
                assert loss > 0 and loss == loss, loss

                # pods diverge after local steps
                p0 = jax.tree.leaves(state2.params)[0]
                assert abs(float(p0[0].mean() - p0[1].mean())) >= 0

                round_step = stepfns.make_fed_round_step(cfg, compress="int8")
                weights = jnp.array([1.0, 3.0])
                state3 = jax.jit(round_step)(state2, weights)
                # after the round, every pod holds the same params
                for leaf in jax.tree.leaves(state3.params):
                    a = jnp.asarray(leaf)
                    assert bool(jnp.allclose(
                        a[0].astype(jnp.float32),
                        a[1].astype(jnp.float32), atol=1e-5)), leaf.shape
            print("FED_OK", loss)
        """)
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.abspath(
            os.path.join(os.path.dirname(__file__), "..", "src")
        )
        env.pop("XLA_FLAGS", None)
        out = subprocess.run(
            [sys.executable, "-c", script], env=env,
            capture_output=True, text=True, timeout=600,
        )
        assert "FED_OK" in out.stdout, out.stdout + out.stderr


@pytest.mark.slow
class TestDryRunSmall:
    """One real dry-run cell in a subprocess (512 fake devices)."""

    def test_olmo_train_cell(self):
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.abspath(
            os.path.join(os.path.dirname(__file__), "..", "src")
        )
        env.pop("XLA_FLAGS", None)
        out = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun",
             "--arch", "olmo-1b", "--shape", "train_4k", "--mesh", "single"],
            env=env, capture_output=True, text=True, timeout=600,
        )
        assert "1/1 cells OK" in out.stdout, out.stdout + out.stderr
