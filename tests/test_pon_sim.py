"""Integration tests for the PON simulator: the paper's headline behaviour."""
import numpy as np
import pytest

from repro.core.slicing import ClientProfile
from repro.net import FLRoundWorkload, OnuQueue, PONConfig, simulate_round
from repro.net.dba import FCFSBestEffort
from repro.net.traffic import PoissonSource, background_rate_for_load

M = 26.416e6


def mk_workload(n=32, seed=0):
    rng = np.random.default_rng(seed)
    clients = [
        ClientProfile(client_id=i, t_ud=float(t), t_dl=0.0, m_ud_bits=M)
        for i, t in enumerate(rng.uniform(1.0, 5.0, n))
    ]
    return FLRoundWorkload(clients=clients, model_bits=M)


class TestOnuQueue:
    def test_fifo_order_across_kinds(self):
        q = OnuQueue(0)
        q.push("bg", 100.0, t=0.0)
        q.push("fl", 50.0, t=1.0)
        served = q.serve(120.0)
        assert served["bg"] == pytest.approx(100.0)
        assert served["fl"] == pytest.approx(20.0)
        assert q.backlog == pytest.approx(30.0)

    def test_kind_filtered_service(self):
        q = OnuQueue(0)
        q.push("bg", 100.0, t=0.0)
        q.push("fl", 50.0, t=1.0)
        served = q.serve(60.0, kind="fl")
        assert served == {"fl": pytest.approx(50.0)}
        assert q.backlog_of("bg") == pytest.approx(100.0)


class TestDBA:
    def test_background_assured_first(self):
        dba = FCFSBestEffort(10e9, 1e-3, 4, efficiency=1.0)
        queues = [OnuQueue(i) for i in range(4)]
        queues[0].push("bg", 6e6, 0.0)
        queues[1].push("fl", 9e6, 0.0)
        grants = dba.grant(queues)
        assert grants[0]["bg"] == pytest.approx(6e6)
        # residual 4e6 goes to the FL queue
        assert grants[1]["fl"] == pytest.approx(4e6)

    def test_fl_fcfs_order_by_hol_age(self):
        dba = FCFSBestEffort(10e9, 1e-3, 4, efficiency=1.0)
        queues = [OnuQueue(i) for i in range(3)]
        queues[0].push("fl", 8e6, t=2.0)
        queues[1].push("fl", 8e6, t=1.0)     # older -> served first
        grants = dba.grant(queues)
        assert grants[1]["fl"] == pytest.approx(8e6)
        assert grants[0]["fl"] == pytest.approx(2e6)


class TestRoundSimulation:
    def test_bs_sync_time_independent_of_load(self):
        cfg = PONConfig(n_onus=32)
        wl = mk_workload(32)
        r_low = simulate_round(cfg, wl, 0.3, "bs", seed=1)
        r_high = simulate_round(cfg, wl, 0.8, "bs", seed=1)
        assert r_high.sync_time == pytest.approx(r_low.sync_time, rel=0.05)

    def test_fcfs_sync_grows_with_load(self):
        cfg = PONConfig(n_onus=32)
        wl = mk_workload(32)
        r_low = simulate_round(cfg, wl, 0.3, "fcfs", seed=1)
        r_high = simulate_round(cfg, wl, 0.85, "fcfs", seed=1)
        assert r_high.sync_time > r_low.sync_time

    def test_bs_beats_fcfs_at_high_load(self):
        cfg = PONConfig(n_onus=32)
        wl = mk_workload(32)
        r_bs = simulate_round(cfg, wl, 0.8, "bs", seed=1)
        r_fcfs = simulate_round(cfg, wl, 0.8, "fcfs", seed=1)
        assert r_bs.sync_time < r_fcfs.sync_time

    def test_bs_sync_pinned_near_compute_bound(self):
        cfg = PONConfig(n_onus=32)
        wl = mk_workload(32)
        r = simulate_round(cfg, wl, 0.8, "bs", seed=1)
        # comm overhead = slice drain, a small fraction of the round
        assert r.comm_overhead < 0.25 * r.sync_time

    def test_all_uploads_complete(self):
        cfg = PONConfig(n_onus=16)
        wl = mk_workload(16)
        for policy in ("fcfs", "bs"):
            r = simulate_round(cfg, wl, 0.5, policy, seed=2)
            assert len(r.ul_done) == 16
            assert r.sync_time < 60.0


class TestTraffic:
    def test_poisson_rate_converges(self):
        rng = np.random.default_rng(0)
        src = PoissonSource(rate_bps=1e9, rng=rng, burst_packets=8.0)
        total = sum(src.arrivals(1e-3) for _ in range(20000))
        assert total / 20.0 == pytest.approx(1e9, rel=0.1)

    def test_background_rate_subtracts_training(self):
        assert background_rate_for_load(0.8, 10e9, 1e9) == pytest.approx(7e9)
        assert background_rate_for_load(0.05, 10e9, 1e9) == 0.0
