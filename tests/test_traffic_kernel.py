"""Counter-based traffic sampler: cross-backend parity + stream pinning.

The sampler's contract: a pure function of (stream key, onu, cycle) —
identical on every backend (numpy host path, XLA oracle, Pallas kernel
in interpret mode), identical under any chunking of the cycle axis
(the regression the per-case numpy RNG failed: its arrival stream
depended on chunk sizes), and distributed as Poisson(λ) bursts of
geometric(1/burst) packets per cycle.
"""
import numpy as np
import pytest

from repro.kernels.traffic import ops
from repro.kernels.traffic import ref as traffic_ref

PKT = 12_000.0
BURST = 16.0


def _sample(key, cycle0, n_cycles, n_onus, lam, backend):
    return ops.sample_arrival_bits(
        key, cycle0, n_cycles, n_onus, lam, 1.0 / BURST, PKT,
        backend=backend,
    )


class TestThreefry:
    def test_matches_jax_threefry(self):
        jex = pytest.importorskip("jax.extend.random")
        import jax.numpy as jnp

        counts = jnp.arange(10, dtype=jnp.uint32)
        key = jnp.array([0xDEADBEEF, 0x12345678], dtype=jnp.uint32)
        expect = jex.threefry_2x32(key, counts)
        x0, x1 = traffic_ref.threefry2x32_ref(
            key[0], key[1], counts[:5], counts[5:]
        )
        got = jnp.concatenate([x0, x1])
        assert bool((expect == got).all())

    def test_numpy_threefry_matches_ref(self):
        rng = np.random.default_rng(0)
        c0 = rng.integers(0, 2**32, 64, dtype=np.uint32)
        c1 = rng.integers(0, 2**32, 64, dtype=np.uint32)
        a0, a1 = ops.threefry2x32_np(np.uint32(7), np.uint32(9), c0, c1)
        b0, b1 = traffic_ref.threefry2x32_ref(7, 9, c0, c1)
        assert np.array_equal(a0, np.asarray(b0))
        assert np.array_equal(a1, np.asarray(b1))


class TestChunkInvariance:
    """Satellite regression: the arrival stream for a fixed
    (seed, case, cycle) must be identical across chunk lengths."""

    def test_stream_pinned_across_chunk_lengths(self):
        key = ops.make_stream_key(seed=7, phase=1, round_index=3)
        full = _sample(key, 0, 300, 16, 0.4, "numpy")
        for splits in ([1, 299], [37, 90, 173], [64, 64, 64, 108],
                       [150, 150]):
            parts, k = [], 0
            for n in splits:
                parts.append(_sample(key, k, n, 16, 0.4, "numpy"))
                k += n
            assert np.array_equal(
                full, np.concatenate(parts, axis=1)
            ), f"chunking {splits} changed the stream"

    def test_seek_matches_prefix(self):
        key = ops.make_stream_key(seed=11, phase=0)
        full = _sample(key, 0, 512, 8, 0.7, "numpy")
        window = _sample(key, 300, 100, 8, 0.7, "numpy")
        assert np.array_equal(full[:, 300:400, :], window)

    def test_stream_fingerprint_pinned(self):
        """Total bits of a fixed window — pins the stream definition
        itself (threefry layout, window scheme, tables) across
        refactors. Update deliberately if the stream format changes."""
        key = ops.make_stream_key(seed=3, phase=1, round_index=2)
        got = _sample(key, 128, 256, 8, 0.5, "numpy")
        assert got.sum() == 209_160_000.0
        assert got[0, :7, 0].tolist() == [
            36000.0, 0.0, 0.0, 0.0, 0.0, 408000.0, 0.0
        ]


class TestBackendParity:
    @pytest.mark.parametrize("cycle0,n_cycles,n_onus", [
        (0, 64, 8), (5, 64, 21), (77, 130, 2), (1000, 200, 37),
        (63, 65, 1),
    ])
    def test_numpy_xla_pallas_identical(self, cycle0, n_cycles, n_onus):
        key = ops.make_stream_key(seed=5, phase=0, round_index=1)
        outs = {
            backend: _sample(key, cycle0, n_cycles, n_onus, 0.6, backend)
            for backend in ("numpy", "xla", "pallas_interpret")
        }
        assert np.array_equal(outs["numpy"], outs["xla"])
        assert np.array_equal(outs["xla"], outs["pallas_interpret"])

    def test_batch_mixed_rates(self):
        keys = np.stack([
            ops.make_stream_key(s, p, r)
            for s in (0, 3) for p in (0, 1) for r in (0, 2)
        ])
        lams = np.linspace(0.05, 3.0, len(keys)).astype(np.float32)
        a = ops.sample_arrival_bits(keys, 900, 150, 19, lams,
                                    1 / BURST, PKT, backend="numpy")
        b = ops.sample_arrival_bits(keys, 900, 150, 19, lams,
                                    1 / BURST, PKT, backend="xla")
        assert np.array_equal(a, b)

    def test_case_independent_of_batch(self):
        key = ops.make_stream_key(seed=9, phase=1)
        other = ops.make_stream_key(seed=10, phase=1)
        solo = _sample(key, 0, 128, 4, 0.5, "numpy")
        batched = ops.sample_arrival_bits(
            np.stack([key, other]), 0, 128, 4,
            np.array([0.5, 1.5], np.float32), 1 / BURST, PKT,
            backend="numpy",
        )
        assert np.array_equal(solo[0], batched[0])


class TestDistribution:
    def test_mean_and_variance(self):
        key = ops.make_stream_key(seed=1, phase=0)
        for lam in (0.1, 0.5, 1.6, 6.0):
            bits = _sample(key, 0, 12_000, 32, lam, "numpy")
            packets = bits / PKT
            p = 1.0 / BURST
            assert packets.mean() == pytest.approx(lam * BURST, rel=0.02)
            assert packets.var() == pytest.approx(
                lam * (2 - p) / p**2, rel=0.05
            )

    def test_zero_rate_is_silent(self):
        key = ops.make_stream_key(seed=1, phase=0)
        assert _sample(key, 0, 100, 4, 0.0, "numpy").sum() == 0.0

    def test_large_window_rate_is_calibrated(self):
        # λ_w = 64·λ > 90 underflows a float32 pmf recurrence — the
        # f64 threshold tables must stay calibrated (regression for the
        # 2.4x over-delivery this produced)
        key = ops.make_stream_key(seed=4, phase=1)
        lam = 1.6
        bits = _sample(key, 0, 20_000, 16, lam, "numpy")
        assert bits.mean() == pytest.approx(lam * BURST * PKT, rel=0.02)

    def test_unknown_backend_raises(self):
        key = ops.make_stream_key(seed=0, phase=0)
        with pytest.raises(ValueError, match="unknown backend"):
            _sample(key, 0, 8, 2, 0.5, "cuda")


class TestEngineChunkInvariance:
    """The engine's results cannot depend on its stream chunk length."""

    def test_sweep_invariant_to_chunk_target(self, monkeypatch):
        from repro.core.slicing import ClientProfile
        from repro.net import engine as E
        from repro.net import FLRoundWorkload, PONConfig, SweepCase

        clients = [
            ClientProfile(client_id=i, t_ud=0.1 + 0.05 * i, t_dl=0.0,
                          m_ud_bits=8e5)
            for i in range(4)
        ]
        wl = FLRoundWorkload(clients=clients, model_bits=8e5)
        cfg = PONConfig(n_onus=4, line_rate_bps=1e9)
        case = SweepCase(workload=wl, load=0.6, policy="fcfs", seed=5)

        def run():
            r = E.simulate_round_sweep(cfg, [case])[0]
            return r.sync_time, r.ul_done

        base_sync, base_ul = run()
        monkeypatch.setattr(E, "_CHUNK_TARGET_CELLS", 1 << 10)
        small_sync, small_ul = run()
        assert small_sync == base_sync
        assert small_ul == base_ul
