"""FL substrate tests: aggregation, compression, selection, server rounds."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.fl import (
    Client,
    CompressorConfig,
    CPSServer,
    FedBuffAggregator,
    LocalTrainConfig,
    SelectionConfig,
    compress_delta,
    compressed_update_bits,
    fedadam_init,
    fedadam_step,
    fedavg,
    select_clients,
)
from repro.models import cnn


def tree(*vals):
    return {"a": jnp.asarray(vals[0]), "b": {"c": jnp.asarray(vals[1])}}


class TestFedAvg:
    def test_weighted_average(self):
        t1 = tree([1.0, 2.0], [[1.0]])
        t2 = tree([3.0, 4.0], [[3.0]])
        avg = fedavg([t1, t2], [1.0, 3.0])
        np.testing.assert_allclose(avg["a"], [2.5, 3.5])
        np.testing.assert_allclose(avg["b"]["c"], [[2.5]])

    def test_permutation_invariance(self):
        t1, t2, t3 = (tree([float(i)], [[float(i)]]) for i in range(3))
        a = fedavg([t1, t2, t3], [1, 2, 3])
        b = fedavg([t3, t1, t2], [3, 1, 2])
        np.testing.assert_allclose(a["a"], b["a"])

    def test_single_client_identity(self):
        t1 = tree([1.5, -2.0], [[0.5]])
        avg = fedavg([t1], [7.0])
        np.testing.assert_allclose(avg["a"], t1["a"])

    def test_fedadam_moves_toward_clients(self):
        g = tree([0.0, 0.0], [[0.0]])
        c = tree([1.0, 1.0], [[1.0]])
        state = fedadam_init(g)
        new_g, state = fedadam_step(g, state, [c], [1.0], lr=0.1)
        assert float(new_g["a"][0]) > 0.0

    def test_fedbuff_flush_at_capacity(self):
        agg = FedBuffAggregator(buffer_size=2, server_lr=1.0)
        g = tree([0.0], [[0.0]])
        d = tree([1.0], [[1.0]])
        assert not agg.add(d, weight=1.0)
        assert agg.add(d, weight=1.0, staleness=3)
        new_g = agg.flush(g)
        assert agg.pending == 0
        assert float(new_g["a"][0]) > 0.0


class TestCompression:
    def test_int8_roundtrip_bounded_error(self):
        key = jax.random.PRNGKey(0)
        delta = {"w": jax.random.normal(key, (256, 64))}
        cfg = CompressorConfig(scheme="int8", error_feedback=False)
        decoded, _, bits = compress_delta(delta, cfg)
        scale = float(jnp.max(jnp.abs(delta["w"]))) / 127.0
        err = float(jnp.max(jnp.abs(decoded["w"] - delta["w"])))
        assert err <= scale * 0.5 + 1e-6
        assert bits == 8 * delta["w"].size + 32

    def test_error_feedback_accumulates_residual(self):
        delta = {"w": jnp.full((64,), 0.001)}
        cfg = CompressorConfig(scheme="topk", topk_frac=0.05,
                               error_feedback=True)
        decoded, err, _ = compress_delta(delta, cfg)
        # what was not transmitted this round is carried in the error state
        np.testing.assert_allclose(
            np.asarray(decoded["w"] + err["w"]), np.asarray(delta["w"]),
            rtol=1e-5,
        )

    def test_compression_shrinks_m_ud(self):
        params = {"w": jnp.zeros((1000,))}
        full = compressed_update_bits(params, CompressorConfig(scheme="none"))
        int8 = compressed_update_bits(params, CompressorConfig(scheme="int8"))
        topk = compressed_update_bits(
            params, CompressorConfig(scheme="topk", topk_frac=0.05)
        )
        assert int8 < full / 3.9
        assert topk <= full / 10


class TestSelection:
    def test_fraction_selection_count(self):
        from repro.core.slicing import ClientProfile

        clients = [ClientProfile(i, 1.0, 0.0, 1e6) for i in range(100)]
        rng = np.random.default_rng(0)
        sel = select_clients(
            clients, SelectionConfig(strategy="fraction", fraction=0.25), rng
        )
        assert len(sel) == 25
        assert len({c.client_id for c in sel}) == 25


def _mk_server(n_clients=4, failure_prob=0.0, scheme="none"):
    key = jax.random.PRNGKey(0)
    params = cnn.init_params(key, n_classes=10, width=1)
    rng = np.random.default_rng(0)
    clients = []
    for i in range(n_clients):
        imgs = rng.normal(size=(16, 28, 28, 1)).astype(np.float32)
        labels = rng.integers(0, 10, size=16).astype(np.int32)
        clients.append(
            Client(
                client_id=i,
                data={"images": imgs, "labels": labels},
                loss_fn=cnn.loss_fn,
                cfg=LocalTrainConfig(lr=0.01, batch_size=8, local_epochs=1),
                t_ud_s=1.0 + i,
            )
        )
    return CPSServer(
        global_params=params,
        clients=clients,
        compression=CompressorConfig(scheme=scheme),
        failure_prob=failure_prob,
        seed=0,
    )


class TestServer:
    def test_round_updates_global_model(self):
        server = _mk_server()
        before = jax.tree.map(jnp.copy, server.global_params)
        log = server.run_round()
        assert log.n_arrived == 4
        diffs = jax.tree.map(
            lambda a, b: float(jnp.max(jnp.abs(a - b))),
            before, server.global_params,
        )
        assert max(jax.tree.leaves(diffs)) > 0.0

    def test_partial_aggregation_under_failures(self):
        server = _mk_server(n_clients=8, failure_prob=0.5)
        log = server.run_round()
        assert 0 <= log.n_arrived <= 8
        # training continues even with failures
        log2 = server.run_round()
        assert log2.round_index == 2

    def test_compressed_rounds_converge_same_direction(self):
        s_plain = _mk_server(scheme="none")
        s_comp = _mk_server(scheme="int8")
        l1 = [s_plain.run_round().mean_loss for _ in range(2)]
        l2 = [s_comp.run_round().mean_loss for _ in range(2)]
        assert l1[-1] < l1[0] * 1.5
        assert l2[-1] < l2[0] * 1.5
