"""Hypothesis property-based tests for the system's invariants."""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (  # noqa: E402
    ClientProfile,
    compute_slice,
    schedule_makespan,
    schedule_slots,
    validate_schedule,
)
from repro.core.round_model import bs_round_time  # noqa: E402
from repro.fl.aggregation import fedavg  # noqa: E402

C = 10e9

client_lists = st.lists(
    st.tuples(
        st.floats(0.1, 30.0),        # t_ud
        st.floats(0.0, 2.0),         # t_dl
        st.floats(1e3, 1e9),         # m_ud bits
    ),
    min_size=1,
    max_size=64,
)


def mk(profile_tuples):
    return [
        ClientProfile(client_id=i, t_ud=t, t_dl=d, m_ud_bits=m)
        for i, (t, d, m) in enumerate(profile_tuples)
    ]


@settings(max_examples=200, deadline=None)
@given(client_lists)
def test_slice_invariants(profiles):
    clients = mk(profiles)
    spec = compute_slice(clients, t_current=0.0, t_round=60.0,
                         capacity_bps=C, h=1)
    # B never exceeds the uplink capacity (the paper's text constraint)
    assert spec.bandwidth_bps <= C * (1 + 1e-9)
    assert spec.bandwidth_bps > 0
    # the window covers every client's readiness
    assert spec.t_min <= min(c.delta for c in clients) + 1e-9
    assert spec.t_max >= max(c.delta for c in clients) - 1e-9
    assert spec.tau > 0
    # the slice always has room for the total training traffic
    total = sum(c.m_ud_bits for c in clients)
    assert spec.bandwidth_bps * spec.tau >= total * (1 - 1e-6)


@settings(max_examples=100, deadline=None)
@given(client_lists)
def test_schedule_invariants(profiles):
    clients = mk(profiles)
    spec = compute_slice(clients, 0.0, 0.0, C, h=1)
    slots = schedule_slots(clients, spec, round_start=0.0)
    validate_schedule(clients, slots, spec, round_start=0.0)
    # every upload finishes within a bounded horizon of the window
    makespan = schedule_makespan(slots)
    drain = sum(c.m_ud_bits for c in clients) / spec.bandwidth_bps
    assert makespan <= max(spec.t_max, spec.t_min + drain) + drain + 1e-6


@settings(max_examples=50, deadline=None)
@given(client_lists)
def test_bs_round_time_at_least_compute_bound(profiles):
    clients = mk(profiles)
    timing = bs_round_time(clients, C)
    assert timing.sync_time >= timing.compute_bound - 1e-9
    assert timing.comm_overhead >= -1e-9


@settings(max_examples=100, deadline=None)
@given(
    st.lists(
        st.lists(st.floats(-1e3, 1e3), min_size=3, max_size=3),
        min_size=1, max_size=8,
    ),
    st.lists(st.floats(0.1, 100.0), min_size=8, max_size=8),
)
def test_fedavg_is_convex_combination(leaves, weights):
    import jax.numpy as jnp

    trees = [{"w": jnp.asarray(l)} for l in leaves]
    w = weights[: len(trees)]
    avg = fedavg(trees, w)
    lo = np.min([l for l in leaves], axis=0)
    hi = np.max([l for l in leaves], axis=0)
    a = np.asarray(avg["w"])
    assert (a >= lo - 1e-3).all() and (a <= hi + 1e-3).all()


@settings(max_examples=50, deadline=None)
@given(st.integers(1, 1000), st.integers(1, 64))
def test_quant_roundtrip_error_bound(n, blocks_pow):
    import jax
    from repro.kernels.quant.ref import roundtrip_ref

    x = jax.random.normal(jax.random.PRNGKey(n), (n,))
    block = min(64 * blocks_pow, 4096)
    rt = roundtrip_ref(x, block=block)
    amax = float(np.abs(np.asarray(x)).max()) if n else 0.0
    assert float(np.abs(np.asarray(rt - x)).max()) <= amax / 127.0 * 0.5 + 1e-6
