"""Compressed federated round: int8 round ≈ fp32 round, bits accounting.

Runs on a single host device — the pod axis is just the leading array
axis, so FedAvg semantics are checkable without a mesh.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.dist import stepfns
from repro.fl.compression import CompressorConfig, compressed_update_bits
from repro.optim.optimizers import OptimizerConfig

N_PODS = 2


@pytest.fixture(scope="module")
def fed_state():
    cfg = get_config("olmo-1b", smoke=True)
    opt = OptimizerConfig(name="adamw", lr=1e-2)
    state = stepfns.init_fed_state(jax.random.PRNGKey(0), cfg, opt, N_PODS)
    # diverge the pods with per-pod noise (~ one round of local steps)
    leaves, treedef = jax.tree.flatten(state.params)
    noisy = [
        l + (0.01 * jax.random.normal(jax.random.PRNGKey(i), l.shape)
             ).astype(l.dtype)
        for i, l in enumerate(leaves)
    ]
    return cfg, state._replace(params=jax.tree.unflatten(treedef, noisy))


def _assert_pods_synced(params):
    for leaf in jax.tree.leaves(params):
        np.testing.assert_allclose(
            np.asarray(leaf[0], np.float32), np.asarray(leaf[1], np.float32)
        )


def test_fp32_round_is_weighted_fedavg(fed_state):
    cfg, state = fed_state
    weights = jnp.array([1.0, 3.0])
    out = jax.jit(stepfns.make_fed_round_step(cfg))(state, weights)
    _assert_pods_synced(out.params)
    leaf = jax.tree.leaves(state.params)[0]
    expect = (1.0 * leaf[0] + 3.0 * leaf[1]) / 4.0
    np.testing.assert_allclose(
        np.asarray(jax.tree.leaves(out.params)[0][0]),
        np.asarray(expect), rtol=1e-6, atol=1e-6,
    )


def test_int8_round_close_to_fp32(fed_state):
    cfg, state = fed_state
    weights = jnp.array([1.0, 3.0])
    fp = jax.jit(stepfns.make_fed_round_step(cfg))(state, weights)
    q8 = jax.jit(stepfns.make_fed_round_step(cfg, compress="int8"))(
        state, weights
    )
    _assert_pods_synced(q8.params)
    # int8 quantises the inter-pod delta (amax ~ 0.05 here), so the
    # reconstruction error is bounded by amax/127 per tensor
    for a, b in zip(jax.tree.leaves(fp.params), jax.tree.leaves(q8.params)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), atol=1e-3
        )


def test_topk_round_syncs_pods(fed_state):
    cfg, state = fed_state
    weights = jnp.ones((N_PODS,))
    out = jax.jit(stepfns.make_fed_round_step(cfg, compress="topk"))(
        state, weights
    )
    _assert_pods_synced(out.params)


@pytest.mark.parametrize("scheme", ["none", "int8", "topk", "int8+topk"])
def test_update_bits_match_compression_accounting(fed_state, scheme):
    cfg, state = fed_state
    one_pod = jax.tree.map(lambda l: l[0], state.params)
    expect = compressed_update_bits(one_pod, CompressorConfig(scheme=scheme))
    assert stepfns.fed_update_bits(cfg, compress=scheme) == expect
    if scheme == "none":
        n_params = sum(l.size for l in jax.tree.leaves(one_pod))
        assert expect == 32 * n_params


def test_unknown_scheme_rejected(fed_state):
    cfg, _ = fed_state
    with pytest.raises(ValueError, match="unknown compression scheme"):
        stepfns.make_fed_round_step(cfg, compress="int4")
    with pytest.raises(ValueError, match="unknown compression scheme"):
        stepfns.fed_update_bits(cfg, compress="in8")


class TestErrorFeedback:
    def test_residual_is_exact_compression_error(self, fed_state):
        cfg, state = fed_state
        weights = jnp.ones((N_PODS,))
        step = jax.jit(stepfns.make_fed_round_step(
            cfg, compress="topk", error_feedback=True
        ))
        res0 = stepfns.init_round_residuals(state)
        out, res1 = step(state, weights, res0)
        _assert_pods_synced(out.params)
        # residual == (delta-from-pod0) - decoded(delta): adding the
        # decoded update back to the residual recovers the raw delta
        from repro.fl.compression import topk_sparsify

        leaf = jax.tree.leaves(state.params)[0]
        r = jax.tree.leaves(res1)[0]
        delta = (leaf - leaf[0][None]).astype(jnp.float32)
        decoded = jax.vmap(lambda d: topk_sparsify(d, 0.05))(delta)
        np.testing.assert_allclose(
            np.asarray(r), np.asarray(delta - decoded), atol=1e-5
        )

    def test_ef_time_average_converges_to_uncompressed(self, fed_state):
        """Error feedback's point: what top-k drops is carried and re-sent,
        so the *average* transmitted update over rounds approaches the
        raw delta — repeating the same EF-less round never improves."""
        cfg, state = fed_state
        weights = jnp.ones((N_PODS,))
        fp = jax.jit(stepfns.make_fed_round_step(cfg))(state, weights)
        noef = jax.jit(stepfns.make_fed_round_step(cfg, compress="topk"))(
            state, weights
        )
        step = jax.jit(stepfns.make_fed_round_step(
            cfg, compress="topk", error_feedback=True
        ))
        res = stepfns.init_round_residuals(state)
        outs = []
        for _ in range(4):
            out, res = step(state, weights, res)
            outs.append(out.params)

        def err(tree):
            return sum(
                float(jnp.sum(jnp.abs(
                    a.astype(jnp.float32) - b.astype(jnp.float32)
                )))
                for a, b in zip(jax.tree.leaves(tree),
                                jax.tree.leaves(fp.params))
            )

        avg_ef = jax.tree.map(
            lambda *ls: sum(l.astype(jnp.float32) for l in ls) / len(ls),
            *outs,
        )
        # deterministic computation: EF must strictly beat repeating the
        # same EF-less round (which never improves however long you run)
        assert err(avg_ef) < 0.95 * err(noef.params)

    def test_none_scheme_passes_residual_through(self, fed_state):
        cfg, state = fed_state
        weights = jnp.ones((N_PODS,))
        step = stepfns.make_fed_round_step(
            cfg, compress="none", error_feedback=True
        )
        res0 = stepfns.init_round_residuals(state)
        out, res1 = step(state, weights, res0)
        _assert_pods_synced(out.params)
        for a, b in zip(jax.tree.leaves(res0), jax.tree.leaves(res1)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_plain_round_step_signature_unchanged(self, fed_state):
        cfg, state = fed_state
        weights = jnp.ones((N_PODS,))
        out = jax.jit(stepfns.make_fed_round_step(cfg, compress="int8"))(
            state, weights
        )
        _assert_pods_synced(out.params)


def test_cosim_config_derives_bits_from_stepfns():
    from repro.fl.simulation import CoSimConfig

    cfg = get_config("olmo-1b", smoke=True)
    cc = CoSimConfig.from_fed_model(cfg, compress="int8")
    # downlink = fp32 broadcast of the global model; uplink = compressed
    assert cc.model_bits == float(stepfns.fed_update_bits(cfg, "none"))
    assert cc.upload_bits == float(stepfns.fed_update_bits(cfg, "int8"))
    assert 0 < cc.upload_bits < cc.model_bits
