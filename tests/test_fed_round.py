"""Compressed federated round: int8 round ≈ fp32 round, bits accounting.

Runs on a single host device — the pod axis is just the leading array
axis, so FedAvg semantics are checkable without a mesh.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.dist import stepfns
from repro.fl.compression import CompressorConfig, compressed_update_bits
from repro.optim.optimizers import OptimizerConfig

N_PODS = 2


@pytest.fixture(scope="module")
def fed_state():
    cfg = get_config("olmo-1b", smoke=True)
    opt = OptimizerConfig(name="adamw", lr=1e-2)
    state = stepfns.init_fed_state(jax.random.PRNGKey(0), cfg, opt, N_PODS)
    # diverge the pods with per-pod noise (~ one round of local steps)
    leaves, treedef = jax.tree.flatten(state.params)
    noisy = [
        l + (0.01 * jax.random.normal(jax.random.PRNGKey(i), l.shape)
             ).astype(l.dtype)
        for i, l in enumerate(leaves)
    ]
    return cfg, state._replace(params=jax.tree.unflatten(treedef, noisy))


def _assert_pods_synced(params):
    for leaf in jax.tree.leaves(params):
        np.testing.assert_allclose(
            np.asarray(leaf[0], np.float32), np.asarray(leaf[1], np.float32)
        )


def test_fp32_round_is_weighted_fedavg(fed_state):
    cfg, state = fed_state
    weights = jnp.array([1.0, 3.0])
    out = jax.jit(stepfns.make_fed_round_step(cfg))(state, weights)
    _assert_pods_synced(out.params)
    leaf = jax.tree.leaves(state.params)[0]
    expect = (1.0 * leaf[0] + 3.0 * leaf[1]) / 4.0
    np.testing.assert_allclose(
        np.asarray(jax.tree.leaves(out.params)[0][0]),
        np.asarray(expect), rtol=1e-6, atol=1e-6,
    )


def test_int8_round_close_to_fp32(fed_state):
    cfg, state = fed_state
    weights = jnp.array([1.0, 3.0])
    fp = jax.jit(stepfns.make_fed_round_step(cfg))(state, weights)
    q8 = jax.jit(stepfns.make_fed_round_step(cfg, compress="int8"))(
        state, weights
    )
    _assert_pods_synced(q8.params)
    # int8 quantises the inter-pod delta (amax ~ 0.05 here), so the
    # reconstruction error is bounded by amax/127 per tensor
    for a, b in zip(jax.tree.leaves(fp.params), jax.tree.leaves(q8.params)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), atol=1e-3
        )


def test_topk_round_syncs_pods(fed_state):
    cfg, state = fed_state
    weights = jnp.ones((N_PODS,))
    out = jax.jit(stepfns.make_fed_round_step(cfg, compress="topk"))(
        state, weights
    )
    _assert_pods_synced(out.params)


@pytest.mark.parametrize("scheme", ["none", "int8", "topk", "int8+topk"])
def test_update_bits_match_compression_accounting(fed_state, scheme):
    cfg, state = fed_state
    one_pod = jax.tree.map(lambda l: l[0], state.params)
    expect = compressed_update_bits(one_pod, CompressorConfig(scheme=scheme))
    assert stepfns.fed_update_bits(cfg, compress=scheme) == expect
    if scheme == "none":
        n_params = sum(l.size for l in jax.tree.leaves(one_pod))
        assert expect == 32 * n_params


def test_unknown_scheme_rejected(fed_state):
    cfg, _ = fed_state
    with pytest.raises(ValueError, match="unknown compression scheme"):
        stepfns.make_fed_round_step(cfg, compress="int4")
    with pytest.raises(ValueError, match="unknown compression scheme"):
        stepfns.fed_update_bits(cfg, compress="in8")


class TestErrorFeedback:
    def test_residual_is_exact_compression_error(self, fed_state):
        cfg, state = fed_state
        weights = jnp.ones((N_PODS,))
        step = jax.jit(stepfns.make_fed_round_step(
            cfg, compress="topk", error_feedback=True
        ))
        res0 = stepfns.init_round_residuals(state)
        out, res1 = step(state, weights, res0)
        _assert_pods_synced(out.params)
        # residual == (delta-from-pod0) - decoded(delta): adding the
        # decoded update back to the residual recovers the raw delta
        from repro.fl.compression import topk_sparsify

        leaf = jax.tree.leaves(state.params)[0]
        r = jax.tree.leaves(res1)[0]
        delta = (leaf - leaf[0][None]).astype(jnp.float32)
        decoded = jax.vmap(lambda d: topk_sparsify(d, 0.05))(delta)
        np.testing.assert_allclose(
            np.asarray(r), np.asarray(delta - decoded), atol=1e-5
        )

    def test_ef_time_average_converges_to_uncompressed(self, fed_state):
        """Error feedback's point: what top-k drops is carried and re-sent,
        so the *average* transmitted update over rounds approaches the
        raw delta — repeating the same EF-less round never improves."""
        cfg, state = fed_state
        weights = jnp.ones((N_PODS,))
        fp = jax.jit(stepfns.make_fed_round_step(cfg))(state, weights)
        noef = jax.jit(stepfns.make_fed_round_step(cfg, compress="topk"))(
            state, weights
        )
        step = jax.jit(stepfns.make_fed_round_step(
            cfg, compress="topk", error_feedback=True
        ))
        res = stepfns.init_round_residuals(state)
        outs = []
        for _ in range(4):
            out, res = step(state, weights, res)
            outs.append(out.params)

        def err(tree):
            return sum(
                float(jnp.sum(jnp.abs(
                    a.astype(jnp.float32) - b.astype(jnp.float32)
                )))
                for a, b in zip(jax.tree.leaves(tree),
                                jax.tree.leaves(fp.params))
            )

        avg_ef = jax.tree.map(
            lambda *ls: sum(l.astype(jnp.float32) for l in ls) / len(ls),
            *outs,
        )
        # deterministic computation: EF must strictly beat repeating the
        # same EF-less round (which never improves however long you run)
        assert err(avg_ef) < 0.95 * err(noef.params)

    def test_none_scheme_passes_residual_through(self, fed_state):
        cfg, state = fed_state
        weights = jnp.ones((N_PODS,))
        step = stepfns.make_fed_round_step(
            cfg, compress="none", error_feedback=True
        )
        res0 = stepfns.init_round_residuals(state)
        out, res1 = step(state, weights, res0)
        _assert_pods_synced(out.params)
        for a, b in zip(jax.tree.leaves(res0), jax.tree.leaves(res1)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_plain_round_step_signature_unchanged(self, fed_state):
        cfg, state = fed_state
        weights = jnp.ones((N_PODS,))
        out = jax.jit(stepfns.make_fed_round_step(cfg, compress="int8"))(
            state, weights
        )
        _assert_pods_synced(out.params)


def test_cosim_config_derives_bits_from_stepfns():
    from repro.fl.simulation import CoSimConfig

    cfg = get_config("olmo-1b", smoke=True)
    cc = CoSimConfig.from_fed_model(cfg, compress="int8")
    # downlink = fp32 broadcast of the global model; uplink = compressed
    assert cc.model_bits == float(stepfns.fed_update_bits(cfg, "none"))
    assert cc.upload_bits == float(stepfns.fed_update_bits(cfg, "int8"))
    assert 0 < cc.upload_bits < cc.model_bits


class TestAsyncFedBuff:
    """Buffered staleness-weighted (FedBuff) rounds on the pod axis."""

    def _setup(self, fed_state):
        cfg, state = fed_state
        # a true global: every pod synced to the same rows (the module
        # fixture's params are pod-diverged, which is NOT a valid
        # post-download state for refs/global)
        synced = jax.tree.map(
            lambda l: jnp.broadcast_to(l[0][None], l.shape),
            state.params,
        )
        astate = stepfns.init_async_state(state._replace(params=synced))
        # per-pod local training moves the params off the global
        leaves, treedef = jax.tree.flatten(synced)
        moved = [
            l + (0.02 * jax.random.normal(jax.random.PRNGKey(100 + i),
                                          l.shape)).astype(l.dtype)
            for i, l in enumerate(leaves)
        ]
        return cfg, state._replace(
            params=jax.tree.unflatten(treedef, moved)
        ), astate

    def test_all_arrived_fresh_equals_fedavg_delta(self, fed_state):
        """With every pod arrived at staleness 0 and server_lr 1, the
        FedBuff merge is exactly FedAvg expressed in delta form."""
        cfg, state, astate = self._setup(fed_state)
        weights = jnp.array([1.0, 3.0])
        step = jax.jit(stepfns.make_async_round_step(cfg))
        ones = jnp.ones((N_PODS,))
        out, astate2 = step(
            state, astate, weights, jnp.ones(N_PODS, bool),
            jnp.zeros(N_PODS, jnp.int32), ones,
            jnp.ones(N_PODS, bool), jnp.ones(N_PODS, bool),
        )
        _assert_pods_synced(out.params)
        expect = jax.jit(stepfns.make_fed_round_step(cfg))(state, weights)
        for a, b in zip(jax.tree.leaves(out.params),
                        jax.tree.leaves(expect.params)):
            np.testing.assert_allclose(
                np.asarray(a, np.float32), np.asarray(b, np.float32),
                atol=1e-5,
            )

    def test_straggler_keeps_params_and_misses_merge(self, fed_state):
        cfg, state, astate = self._setup(fed_state)
        weights = jnp.ones((N_PODS,))
        step = jax.jit(stepfns.make_async_round_step(cfg))
        arrived = jnp.array([True, False])
        out, astate2 = step(
            state, astate, weights, arrived,
            jnp.zeros(N_PODS, jnp.int32), jnp.ones((N_PODS,)),
            jnp.ones(N_PODS, bool), arrived,
        )
        p_new = jax.tree.leaves(out.params)[0]
        p_old = jax.tree.leaves(state.params)[0]
        g_new = jax.tree.leaves(astate2.global_params)[0]
        # straggler pod 1 keeps its local params and its old ref
        np.testing.assert_array_equal(np.asarray(p_new[1]),
                                      np.asarray(p_old[1]))
        r_old = jax.tree.leaves(astate.refs)[0]
        r_new = jax.tree.leaves(astate2.refs)[0]
        np.testing.assert_array_equal(np.asarray(r_new[1]),
                                      np.asarray(r_old[1]))
        # arrived pod 0 resynced to the new global (params and ref)
        np.testing.assert_array_equal(np.asarray(p_new[0]),
                                      np.asarray(g_new[0]))
        np.testing.assert_array_equal(np.asarray(r_new[0]),
                                      np.asarray(g_new[0]))
        # global moved by pod 0's full delta (only contributor)
        g_old = jax.tree.leaves(astate.global_params)[0]
        delta0 = np.asarray(p_old[0], np.float32) - np.asarray(
            g_old[0], np.float32
        )
        np.testing.assert_allclose(
            np.asarray(g_new[0], np.float32),
            np.asarray(g_old[0], np.float32) + delta0, atol=1e-5,
        )

    def test_staleness_discounts_contribution(self, fed_state):
        """A stale pod's delta moves the global less than a fresh one's
        — and the weighting matches 1/sqrt(1+tau)."""
        cfg, state, astate = self._setup(fed_state)
        weights = jnp.ones((N_PODS,))
        step = jax.jit(stepfns.make_async_round_step(cfg))

        def merge(stale):
            out, ast = step(
                state, astate, weights, jnp.ones(N_PODS, bool),
                jnp.asarray(stale, jnp.int32), jnp.ones((N_PODS,)),
                jnp.ones(N_PODS, bool), jnp.ones(N_PODS, bool),
            )
            return jax.tree.leaves(ast.global_params)[0][0]

        g0 = np.asarray(merge([0, 0]), np.float32)
        g3 = np.asarray(merge([0, 3]), np.float32)
        g_old = np.asarray(jax.tree.leaves(astate.global_params)[0][0],
                           np.float32)
        p = jax.tree.leaves(state.params)[0]
        d0 = np.asarray(p[0], np.float32) - g_old
        d1 = np.asarray(p[1], np.float32) - g_old
        np.testing.assert_allclose(g0, g_old + (d0 + d1) / 2.0, atol=1e-5)
        # data weights mix relatively (1/2 each); staleness discounts
        # the stale pod's share absolutely
        s = 1.0 / np.sqrt(4.0)
        np.testing.assert_allclose(
            g3, g_old + (d0 + s * d1) / 2.0, atol=1e-5
        )

    def test_partial_fraction_scales_weight(self, fed_state):
        cfg, state, astate = self._setup(fed_state)
        weights = jnp.ones((N_PODS,))
        step = jax.jit(stepfns.make_async_round_step(cfg))
        out, ast = step(
            state, astate, weights, jnp.ones(N_PODS, bool),
            jnp.zeros(N_PODS, jnp.int32), jnp.array([1.0, 0.5]),
            jnp.ones(N_PODS, bool), jnp.ones(N_PODS, bool),
        )
        g_old = np.asarray(jax.tree.leaves(astate.global_params)[0][0],
                           np.float32)
        p = jax.tree.leaves(state.params)[0]
        d0 = np.asarray(p[0], np.float32) - g_old
        d1 = np.asarray(p[1], np.float32) - g_old
        g = np.asarray(jax.tree.leaves(ast.global_params)[0][0],
                       np.float32)
        # the half-served update contributes half its (relative) share
        np.testing.assert_allclose(
            g, g_old + (d0 + 0.5 * d1) / 2.0, atol=1e-5
        )

    def test_no_arrivals_is_noop_on_global(self, fed_state):
        cfg, state, astate = self._setup(fed_state)
        step = jax.jit(stepfns.make_async_round_step(cfg))
        out, ast = step(
            state, astate, jnp.ones((N_PODS,)),
            jnp.zeros(N_PODS, bool), jnp.zeros(N_PODS, jnp.int32),
            jnp.ones((N_PODS,)), jnp.ones(N_PODS, bool),
            jnp.zeros(N_PODS, bool),
        )
        for a, b in zip(jax.tree.leaves(astate.global_params),
                        jax.tree.leaves(ast.global_params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(state.params),
                        jax.tree.leaves(out.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_snapshot_freezes_inflight_payload(self, fed_state):
        """Training that happens after the snapshot must not leak into
        the pending upload: merging the straggler later applies the
        snapshotted delta, not the drifted params."""
        cfg, state, astate = self._setup(fed_state)
        weights = jnp.ones((N_PODS,))
        step = jax.jit(stepfns.make_async_round_step(cfg))
        none = jnp.zeros(N_PODS, bool)
        # round 1: snap both pods, nobody arrives
        _, ast1 = step(
            state, astate, weights, none, jnp.zeros(N_PODS, jnp.int32),
            jnp.ones((N_PODS,)), jnp.ones(N_PODS, bool), none,
        )
        # pod params drift afterwards (local steps while uploading)
        drifted = state._replace(params=jax.tree.map(
            lambda l: l + jnp.asarray(1.0, l.dtype), state.params
        ))
        # round 2: pod 1 arrives; no new snapshot
        arrived = jnp.array([False, True])
        _, ast2 = step(
            drifted, ast1, weights, arrived,
            jnp.array([1, 1], jnp.int32), jnp.ones((N_PODS,)),
            none, arrived,
        )
        g_old = np.asarray(jax.tree.leaves(astate.global_params)[0][0],
                           np.float32)
        p = jax.tree.leaves(state.params)[0]
        d1 = np.asarray(p[1], np.float32) - g_old
        # lone arrival at staleness 1: the SNAPSHOTTED delta applies,
        # discounted absolutely by 1/sqrt(2) — not the drifted params,
        # and not the full delta
        s = 1.0 / np.sqrt(2.0)
        g = np.asarray(jax.tree.leaves(ast2.global_params)[0][0],
                       np.float32)
        np.testing.assert_allclose(g, g_old + s * d1, atol=1e-5)

    def test_error_feedback_masks_stragglers(self, fed_state):
        cfg, state, astate = self._setup(fed_state)
        weights = jnp.ones((N_PODS,))
        step = jax.jit(stepfns.make_async_round_step(
            cfg, compress="topk", error_feedback=True
        ))
        res0 = stepfns.init_round_residuals(state)
        arrived = jnp.array([True, False])
        out, ast, res1 = step(
            state, astate, weights, arrived,
            jnp.zeros(N_PODS, jnp.int32), jnp.ones((N_PODS,)),
            jnp.ones(N_PODS, bool), arrived, res0,
        )
        r = jax.tree.leaves(res1)[0]
        assert float(jnp.abs(r[0]).max()) > 0.0
        assert float(jnp.abs(r[1]).max()) == 0.0

    def test_host_mirror_parity(self, fed_state):
        """fedops.fedbuff_pods == fl.aggregation.fedbuff_merge on the
        same deltas/weights/staleness."""
        from repro.dist import fedops
        from repro.fl.aggregation import fedbuff_merge

        _, state = fed_state
        g_leaf = jax.tree.leaves(state.params)[0][0].astype(jnp.float32)
        deltas = [
            {"w": 0.1 * jax.random.normal(jax.random.PRNGKey(i),
                                          g_leaf.shape)}
            for i in range(N_PODS)
        ]
        glob = {"w": g_leaf}
        host = fedbuff_merge(glob, deltas, [1.0, 2.0], [0, 2])
        pend = {"w": jnp.stack([d["w"] for d in deltas])}
        gp = {"w": jnp.broadcast_to(g_leaf[None],
                                    (N_PODS,) + g_leaf.shape)}
        pods = fedops.fedbuff_pods(
            pend, gp, jnp.array([1.0, 2.0]), jnp.ones(N_PODS, bool),
            jnp.array([0, 2]),
        )
        np.testing.assert_allclose(
            np.asarray(host["w"]), np.asarray(pods["w"][0]), rtol=1e-6,
            atol=1e-6,
        )
