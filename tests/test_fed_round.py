"""Compressed federated round: int8 round ≈ fp32 round, bits accounting.

Runs on a single host device — the pod axis is just the leading array
axis, so FedAvg semantics are checkable without a mesh.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.dist import stepfns
from repro.fl.compression import CompressorConfig, compressed_update_bits
from repro.optim.optimizers import OptimizerConfig

N_PODS = 2


@pytest.fixture(scope="module")
def fed_state():
    cfg = get_config("olmo-1b", smoke=True)
    opt = OptimizerConfig(name="adamw", lr=1e-2)
    state = stepfns.init_fed_state(jax.random.PRNGKey(0), cfg, opt, N_PODS)
    # diverge the pods with per-pod noise (~ one round of local steps)
    leaves, treedef = jax.tree.flatten(state.params)
    noisy = [
        l + (0.01 * jax.random.normal(jax.random.PRNGKey(i), l.shape)
             ).astype(l.dtype)
        for i, l in enumerate(leaves)
    ]
    return cfg, state._replace(params=jax.tree.unflatten(treedef, noisy))


def _assert_pods_synced(params):
    for leaf in jax.tree.leaves(params):
        np.testing.assert_allclose(
            np.asarray(leaf[0], np.float32), np.asarray(leaf[1], np.float32)
        )


def test_fp32_round_is_weighted_fedavg(fed_state):
    cfg, state = fed_state
    weights = jnp.array([1.0, 3.0])
    out = jax.jit(stepfns.make_fed_round_step(cfg))(state, weights)
    _assert_pods_synced(out.params)
    leaf = jax.tree.leaves(state.params)[0]
    expect = (1.0 * leaf[0] + 3.0 * leaf[1]) / 4.0
    np.testing.assert_allclose(
        np.asarray(jax.tree.leaves(out.params)[0][0]),
        np.asarray(expect), rtol=1e-6, atol=1e-6,
    )


def test_int8_round_close_to_fp32(fed_state):
    cfg, state = fed_state
    weights = jnp.array([1.0, 3.0])
    fp = jax.jit(stepfns.make_fed_round_step(cfg))(state, weights)
    q8 = jax.jit(stepfns.make_fed_round_step(cfg, compress="int8"))(
        state, weights
    )
    _assert_pods_synced(q8.params)
    # int8 quantises the inter-pod delta (amax ~ 0.05 here), so the
    # reconstruction error is bounded by amax/127 per tensor
    for a, b in zip(jax.tree.leaves(fp.params), jax.tree.leaves(q8.params)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), atol=1e-3
        )


def test_topk_round_syncs_pods(fed_state):
    cfg, state = fed_state
    weights = jnp.ones((N_PODS,))
    out = jax.jit(stepfns.make_fed_round_step(cfg, compress="topk"))(
        state, weights
    )
    _assert_pods_synced(out.params)


@pytest.mark.parametrize("scheme", ["none", "int8", "topk", "int8+topk"])
def test_update_bits_match_compression_accounting(fed_state, scheme):
    cfg, state = fed_state
    one_pod = jax.tree.map(lambda l: l[0], state.params)
    expect = compressed_update_bits(one_pod, CompressorConfig(scheme=scheme))
    assert stepfns.fed_update_bits(cfg, compress=scheme) == expect
    if scheme == "none":
        n_params = sum(l.size for l in jax.tree.leaves(one_pod))
        assert expect == 32 * n_params


def test_unknown_scheme_rejected(fed_state):
    cfg, _ = fed_state
    with pytest.raises(ValueError, match="unknown compression scheme"):
        stepfns.make_fed_round_step(cfg, compress="int4")
    with pytest.raises(ValueError, match="unknown compression scheme"):
        stepfns.fed_update_bits(cfg, compress="in8")


def test_cosim_config_derives_bits_from_stepfns():
    from repro.fl.simulation import CoSimConfig

    cfg = get_config("olmo-1b", smoke=True)
    cc = CoSimConfig.from_fed_model(cfg, compress="int8")
    # downlink = fp32 broadcast of the global model; uplink = compressed
    assert cc.model_bits == float(stepfns.fed_update_bits(cfg, "none"))
    assert cc.upload_bits == float(stepfns.fed_update_bits(cfg, "int8"))
    assert 0 < cc.upload_bits < cc.model_bits
