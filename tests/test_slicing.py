"""Unit tests for the BS algorithm (Algorithm 1) and the slot scheduler."""
import numpy as np
import pytest

from repro.core import (
    ClientProfile,
    SliceManager,
    compute_slice,
    greedy_max_clients,
    map_to_polling_cycles,
    min_round_time,
    schedule_makespan,
    schedule_slots,
    select_by_deadline,
    validate_round_deadline,
    validate_schedule,
)

C = 10e9
M = 26.416e6


def mk_clients(t_uds, m_bits=M, t_dl=0.01):
    return [
        ClientProfile(client_id=i, t_ud=t, t_dl=t_dl, m_ud_bits=m_bits)
        for i, t in enumerate(t_uds)
    ]


class TestComputeSlice:
    def test_window_matches_heterogeneity_gap(self):
        clients = mk_clients([1.0, 3.0, 5.0])
        spec = compute_slice(clients, t_current=0.0, t_round=10.0,
                             capacity_bps=C, h=1)
        assert spec.t_min == pytest.approx(1.01)
        # t_max = max delta + nabla (straggler serialization + propagation)
        nabla = M / C + 20e3 / 2e8
        assert spec.t_max == pytest.approx(5.01 + nabla)
        assert spec.tau == pytest.approx(spec.t_max - spec.t_min)

    def test_bandwidth_is_demand_over_window(self):
        clients = mk_clients([1.0, 5.0])
        # the paper's line 8 exactly
        spec_paper = compute_slice(clients, 0.0, 10.0, C, h=1,
                                   sizing="paper")
        assert spec_paper.bandwidth_bps == pytest.approx(
            2 * M / spec_paper.tau
        )
        assert spec_paper.feasible
        # default deadline sizing can only demand MORE (meets t_e)
        spec = compute_slice(clients, 0.0, 10.0, C, h=1)
        assert spec.bandwidth_bps >= spec_paper.bandwidth_bps - 1e-6
        assert spec.demanded_bps >= 2 * M / spec.tau - 1e-6

    def test_bandwidth_capped_at_capacity(self):
        # nearly-homogeneous clients -> tiny window -> capped at C
        clients = mk_clients([1.0, 1.0 + 1e-6] * 64)
        spec = compute_slice(clients, 0.0, 10.0, C, h=1)
        assert spec.bandwidth_bps <= C
        assert not spec.feasible
        # the window was widened so everything still fits at line rate
        assert spec.tau >= (128 * M) / C * (1 - 1e-9)

    def test_slice_times_include_round_offset(self):
        clients = mk_clients([1.0, 2.0])
        t_round = 7.5
        spec = compute_slice(clients, t_current=100.0, t_round=t_round, h=3,
                             capacity_bps=C)
        assert spec.t_start == pytest.approx(100.0 + spec.t_min + 3 * t_round)
        assert spec.t_end == pytest.approx(100.0 + spec.t_max + 3 * t_round)

    def test_h_must_be_positive(self):
        with pytest.raises(ValueError):
            compute_slice(mk_clients([1.0]), 0.0, 1.0, C, h=0)

    def test_empty_clients_rejected(self):
        with pytest.raises(ValueError):
            compute_slice([], 0.0, 1.0, C)

    def test_round_deadline_validation(self):
        clients = mk_clients([1.0, 5.0])
        spec = compute_slice(clients, 0.0, 10.0, C, h=1)
        assert validate_round_deadline(clients, spec, t_round=10.0)
        assert not validate_round_deadline(clients, spec, t_round=1.0)
        assert min_round_time(clients, C) == pytest.approx(spec.t_max)


class TestScheduler:
    def test_slots_satisfy_invariants(self):
        rng = np.random.default_rng(0)
        clients = mk_clients(rng.uniform(1, 5, 32))
        spec = compute_slice(clients, 0.0, 0.0, C, h=1)
        slots = schedule_slots(clients, spec, round_start=0.0)
        validate_schedule(clients, slots, spec, round_start=0.0)

    def test_makespan_close_to_t_max(self):
        # with B = sum M / tau, back-to-back slots end near the window end
        rng = np.random.default_rng(1)
        clients = mk_clients(rng.uniform(1, 5, 128))
        spec = compute_slice(clients, 0.0, 0.0, C, h=1)
        slots = schedule_slots(clients, spec, round_start=0.0)
        makespan = schedule_makespan(slots)
        assert makespan <= spec.t_max + spec.duration * 0.5
        assert makespan >= spec.t_min

    def test_polling_cycle_grants_conserve_bits(self):
        clients = mk_clients([1.0, 2.0, 4.0])
        spec = compute_slice(clients, 0.0, 0.0, C, h=1)
        slots = schedule_slots(clients, spec, round_start=0.0)
        grants = map_to_polling_cycles(slots, spec, cycle_time_s=1e-3)
        per_client = {}
        for g in grants:
            per_client[g.client_id] = per_client.get(g.client_id, 0.0) + g.bits
        for c in clients:
            assert per_client[c.client_id] == pytest.approx(
                c.m_ud_bits, rel=1e-6
            )


class TestMembership:
    def test_slice_recomputed_only_on_change(self):
        mgr = SliceManager(capacity_bps=C, t_round=10.0)
        mgr.bootstrap(mk_clients([1.0, 2.0]))
        assert mgr.recompute_count == 1
        for t in range(5):
            mgr.on_round(float(t))
        assert mgr.recompute_count == 1          # rounds don't retrigger
        mgr.join(ClientProfile(99, 3.0, 0.01, M), t_now=5.0)
        assert mgr.recompute_count == 2
        mgr.leave(99, t_now=6.0)
        assert mgr.recompute_count == 3
        mgr.leave(12345, t_now=7.0)              # unknown: no-op
        assert mgr.recompute_count == 3

    def test_all_leave_clears_slice(self):
        mgr = SliceManager(capacity_bps=C, t_round=10.0)
        mgr.bootstrap(mk_clients([1.0]))
        mgr.leave(0, t_now=1.0)
        assert mgr.current_slice is None


class TestDeadline:
    def test_deadline_filters_stragglers(self):
        clients = mk_clients([1.0, 2.0, 9.0])
        sel, dropped = select_by_deadline(clients, deadline_s=5.0,
                                          uplink_bps=C)
        assert {c.client_id for c in sel} == {0, 1}
        assert {c.client_id for c in dropped} == {2}

    def test_greedy_packs_in_readiness_order(self):
        clients = mk_clients([1.0, 1.1, 1.2, 50.0])
        chosen = greedy_max_clients(clients, deadline_s=5.0, uplink_bps=C)
        assert {c.client_id for c in chosen} == {0, 1, 2}
