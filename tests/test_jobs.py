"""Multi-tenant jobs (``repro.net.jobs``): engine vs the cycle-level
oracle, fairness-split properties, cadenced timelines and the
single-job bitwise pin.

The batched engine's jobs path must reproduce the cycle-by-cycle dict
oracle (``simulate_jobs_round_reference``) at rtol 1e-6 across both
DBA policies, all three fairness policies, offset job counts and
multi-PON topologies — both sides consume the identical counter
streams and the identical ``job_fair_split`` arithmetic, so only the
cycle sequencing can drift.  A degenerate all-single-job sweep must
normalise to the plain single-tenant path bit-for-bit (pinned at the
PR 3 operating point).
"""
import numpy as np
import pytest

from repro.core.slicing import ClientProfile
from repro.net import (
    FLRoundWorkload,
    JobSpec,
    MultiPonTopology,
    PONConfig,
    SweepCase,
    SweepSpec,
    TimelineSchedule,
    job_fair_split,
    make_competing_jobs,
    simulate,
    simulate_jobs_round_reference,
    simulate_timeline_per_round,
)

CFG = PONConfig(n_onus=8, line_rate_bps=1e9)

OP_POINT_SYNC = 5.058100000000024     # PR 3 Fig. 2b 0.8-load pin


def _clients(ids, seed=0, m_lo=1e5, m_hi=1e6):
    rng = np.random.default_rng(seed)
    return [
        ClientProfile(client_id=int(i),
                      t_ud=float(rng.uniform(0.05, 0.5)), t_dl=0.0,
                      m_ud_bits=float(rng.uniform(m_lo, m_hi)))
        for i in ids
    ]


def _mk_jobs(ids, n_jobs, weights=None, deadlines=None, cadence=None):
    """Round-robin partition of ``ids`` into ``n_jobs`` JobSpecs."""
    jobs = []
    for j in range(n_jobs):
        cad = cadence[j] if cadence else (1, 0)
        jobs.append(JobSpec(
            job_id=j,
            clients=tuple(i for k, i in enumerate(ids) if k % n_jobs == j),
            model_bits=4e5 * (j + 1),
            weight=weights[j] if weights else 1.0,
            deadline_s=deadlines[j] if deadlines else None,
            period=cad[0], phase=cad[1],
        ))
    return tuple(jobs)


def _mk_case(n_clients=6, n_jobs=2, policy="bs", fairness="maxmin",
             topology=None, load=0.6, seed=0, **job_kw):
    clients = _clients(range(n_clients), seed=seed)
    jobs = _mk_jobs([c.client_id for c in clients], n_jobs, **job_kw)
    wl = FLRoundWorkload(clients=clients, model_bits=4e5)
    return SweepCase(workload=wl, load=load, policy=policy, seed=seed,
                     topology=topology, jobs=jobs, fairness=fairness)


def _assert_parity(ref, eng, rtol=1e-6):
    for name in ("dl_done", "ready", "ul_done"):
        a, b = getattr(ref, name), getattr(eng, name)
        assert set(a) == set(b)
        for cid in a:
            assert b[cid] == pytest.approx(a[cid], rel=rtol, abs=1e-12), (
                f"{name}[{cid}]: oracle={a[cid]} engine={b[cid]}"
            )
    assert eng.sync_time == pytest.approx(ref.sync_time, rel=rtol)
    assert set(eng.job_stats) == set(ref.job_stats)
    for jid, rj in ref.job_stats.items():
        ej = eng.job_stats[jid]
        assert ej.sync_time == pytest.approx(rj.sync_time, rel=rtol)
        assert ej.n_clients == rj.n_clients
        for tier in ("onu_done", "olt_done"):
            ra, ea = getattr(rj, tier), getattr(ej, tier)
            assert set(ra) == set(ea)
            for k in ra:
                assert ea[k] == pytest.approx(ra[k], rel=rtol,
                                              abs=1e-12)


class TestEngineOracleParity:
    @pytest.mark.parametrize("policy", ["fcfs", "bs"])
    @pytest.mark.parametrize("fairness", ["maxmin", "weighted",
                                          "deadline"])
    @pytest.mark.parametrize("n_jobs", [2, 3])
    def test_single_pon(self, policy, fairness, n_jobs):
        kw = {}
        if fairness == "weighted":
            kw["weights"] = [1.0 + j for j in range(n_jobs)]
        if fairness == "deadline":
            kw["deadlines"] = [4.0 - j for j in range(n_jobs)]
        case = _mk_case(n_clients=6, n_jobs=n_jobs, policy=policy,
                        fairness=fairness, load=0.7, **kw)
        eng = simulate(SweepSpec(cases=(case,), pon=CFG))[0]
        ref = simulate_jobs_round_reference(CFG, case)
        _assert_parity(ref, eng)

    @pytest.mark.parametrize("policy", ["fcfs", "bs"])
    @pytest.mark.parametrize("fairness", ["maxmin", "weighted"])
    def test_multi_pon_cps(self, policy, fairness):
        """2 PONs × 2 jobs contending on a tight CPS uplink."""
        # cps cap above the mean background offer (0.7 x 2 x 1e9)
        # but below the 2e9 aggregate: FL contends, nothing saturates
        topo = MultiPonTopology(n_pons=2, cps_rate_bps=1.9e9)
        kw = {"weights": [1.0, 3.0]} if fairness == "weighted" else {}
        case = _mk_case(n_clients=8, n_jobs=2, policy=policy,
                        fairness=fairness, topology=topo, load=0.7,
                        **kw)
        eng = simulate(SweepSpec(cases=(case,), pon=CFG))[0]
        ref = simulate_jobs_round_reference(CFG, case)
        _assert_parity(ref, eng)

    def test_batched_cases_match_solo_runs(self):
        """Stacking multi-job cases in one sweep changes nothing."""
        cases = [
            _mk_case(n_jobs=2, policy=p, fairness="maxmin", seed=s)
            for p in ("fcfs", "bs") for s in (0, 1)
        ]
        batched = simulate(SweepSpec(cases=tuple(cases), pon=CFG))
        for case, got in zip(cases, batched):
            solo = simulate(SweepSpec(cases=(case,), pon=CFG))[0]
            assert got.sync_time == solo.sync_time
            assert got.ul_done == solo.ul_done

    def test_jit_backend_falls_back_to_numpy(self):
        """Multi-job sweeps silently clear use_jit: identical results."""
        case = _mk_case(n_jobs=2, policy="bs")
        a = simulate(SweepSpec(cases=(case,), pon=CFG))[0]
        b = simulate(SweepSpec(cases=(case,), pon=CFG,
                               backend="jit"))[0]
        assert b.sync_time == a.sync_time
        assert b.ul_done == a.ul_done


class TestSingleJobNormalisation:
    def _op_case(self, jobs=None):
        rng = np.random.default_rng(42)
        t_uds = rng.uniform(1.0, 5.0, 128)
        clients = [
            ClientProfile(client_id=i, t_ud=float(t_uds[i]), t_dl=0.0,
                          m_ud_bits=26.416e6)
            for i in range(12)
        ]
        wl = FLRoundWorkload(clients=clients, model_bits=26.416e6)
        return SweepCase(workload=wl, load=0.8, policy="fcfs", seed=1,
                         jobs=jobs)

    def test_bitwise_pin(self):
        """An all-single-job sweep runs the plain path bit-for-bit."""
        jobs = (JobSpec(job_id=0, clients=tuple(range(12)),
                        model_bits=26.416e6),)
        plain = simulate(SweepSpec(cases=(self._op_case(),),
                                   pon=PONConfig(n_onus=128)))[0]
        tenant = simulate(SweepSpec(cases=(self._op_case(jobs),),
                                    pon=PONConfig(n_onus=128)))[0]
        assert plain.sync_time == OP_POINT_SYNC
        assert tenant.sync_time == OP_POINT_SYNC      # exact, no rtol
        assert tenant.ul_done == plain.ul_done
        assert tenant.job_stats is not None
        assert tenant.job_stats[0].sync_time == OP_POINT_SYNC
        assert tenant.job_stats[0].n_clients == 12

    def test_single_job_keeps_deadline_knobs(self):
        """Normalised single-job sweeps may use single-tenant knobs."""
        jobs = (JobSpec(job_id=0, clients=tuple(range(12)),
                        model_bits=26.416e6),)
        res = simulate(SweepSpec(cases=(self._op_case(jobs),),
                                 pon=PONConfig(n_onus=128),
                                 ul_deadline_s=4.0))[0]
        assert res.sync_time <= OP_POINT_SYNC


class TestJobFairSplit:
    def test_passthrough_under_cap(self):
        d = np.array([[1.0, 2.0, 3.0], [0.5, 0.0, 1.0]])
        for fairness in ("maxmin", "weighted", "deadline"):
            out = job_fair_split(d, 100.0, fairness,
                                 weights=[1.0, 2.0, 3.0],
                                 slack=[3.0, 2.0, 1.0])
            np.testing.assert_array_equal(out, d)

    def test_bounds_and_conservation(self):
        rng = np.random.default_rng(7)
        d = rng.uniform(0.0, 10.0, (20, 4))
        cap = rng.uniform(2.0, 25.0, 20)
        for fairness in ("maxmin", "weighted", "deadline"):
            out = job_fair_split(d, cap, fairness,
                                 weights=rng.uniform(0.5, 2.0, 4),
                                 slack=rng.uniform(0.0, 5.0, (20, 4)))
            assert np.all(out <= d + 1e-9)
            assert np.all(out >= -1e-12)
            assert np.all(out.sum(axis=1) <= cap + 1e-6)
            over = d.sum(axis=1) > cap
            got = out.sum(axis=1)[over]
            np.testing.assert_allclose(got, cap[over], rtol=1e-9)

    def test_unit_weights_bitwise_maxmin(self):
        rng = np.random.default_rng(3)
        d = rng.uniform(0.0, 10.0, (16, 3))
        cap = rng.uniform(2.0, 20.0, 16)
        a = job_fair_split(d, cap, "maxmin")
        b = job_fair_split(d, cap, "weighted",
                           weights=np.ones(3))
        np.testing.assert_array_equal(a, b)

    def test_weighted_shares_follow_weights(self):
        out = job_fair_split([10.0, 10.0], 6.0, "weighted",
                             weights=[1.0, 2.0])
        np.testing.assert_allclose(out, [2.0, 4.0], rtol=1e-12)

    def test_deadline_earliest_slack_first(self):
        out = job_fair_split([4.0, 4.0, 4.0], 6.0, "deadline",
                             slack=[3.0, 0.5, 1.0])
        np.testing.assert_allclose(out, [0.0, 4.0, 2.0], rtol=1e-12)

    def test_unknown_policy_raises(self):
        with pytest.raises(ValueError, match="unknown fairness"):
            job_fair_split([1.0], 1.0, "roundrobin")


class TestJobSpecAndHelpers:
    def test_cadence(self):
        job = JobSpec(job_id=1, clients=(0,), model_bits=1e5,
                      period=3, phase=2)
        assert [job.active_in(r) for r in range(7)] == [
            False, False, True, False, False, True, False
        ]

    def test_make_competing_jobs(self):
        jobs, profs = make_competing_jobs([0, 1, 2], 1e6, n_jobs=2,
                                          clients_each=2)
        assert [j.job_id for j in jobs] == [1, 2]
        assert jobs[0].clients == (3, 4)
        assert jobs[1].clients == (5, 6)
        assert all(j.model_bits == 5e5 for j in jobs)
        assert [p.client_id for p in profs] == [3, 4, 5, 6]

    def test_partition_validation(self):
        clients = _clients(range(4))
        wl = FLRoundWorkload(clients=clients, model_bits=4e5)
        overlap = (
            JobSpec(job_id=0, clients=(0, 1), model_bits=1e5),
            JobSpec(job_id=1, clients=(1, 2, 3), model_bits=1e5),
        )
        case = SweepCase(workload=wl, load=0.5, policy="fcfs",
                         jobs=overlap)
        with pytest.raises(ValueError, match="belongs to jobs"):
            simulate(SweepSpec(cases=(case,), pon=CFG))
        hole = (JobSpec(job_id=0, clients=(0, 1, 2), model_bits=1e5),)
        case = SweepCase(workload=wl, load=0.5, policy="fcfs",
                         jobs=hole)
        with pytest.raises(ValueError, match="partition"):
            simulate(SweepSpec(cases=(case,), pon=CFG))

    def test_multi_job_rejects_single_tenant_knobs(self):
        case = _mk_case(n_jobs=2)
        with pytest.raises(ValueError, match="per-job deadlines"):
            simulate(SweepSpec(cases=(case,), pon=CFG,
                               ul_deadline_s=1.0))


class TestArrivalShapeValidation:
    """PR 9 satellite: injected arrival matrices must span the full
    ``n_pons * n_onus`` ONU axis — both phases, with a clear error."""

    @pytest.mark.parametrize("field", ["dl_arrivals", "ul_arrivals"])
    def test_wrong_width_raises(self, field):
        clients = _clients(range(6))
        wl = FLRoundWorkload(clients=clients, model_bits=4e5)
        bad = np.zeros((5, CFG.n_onus))     # needs 2 * n_onus columns
        case = SweepCase(workload=wl, load=0.5, policy="fcfs",
                         topology=MultiPonTopology(n_pons=2),
                         **{field: bad})
        with pytest.raises(ValueError, match=r"n_pons \* n_onus"):
            simulate(SweepSpec(cases=(case,), pon=CFG))

    def test_right_width_accepted(self):
        clients = _clients(range(6))
        wl = FLRoundWorkload(clients=clients, model_bits=4e5)
        arr = np.zeros((5, 2 * CFG.n_onus))
        case = SweepCase(workload=wl, load=0.5, policy="fcfs",
                         topology=MultiPonTopology(n_pons=2),
                         dl_arrivals=arr, ul_arrivals=arr)
        res = simulate(SweepSpec(cases=(case,), pon=CFG))[0]
        assert np.isfinite(res.sync_time)


class TestJobTimelines:
    def _spec(self, n_rounds=4, cadence=None, n_jobs=3):
        case = _mk_case(n_clients=6, n_jobs=n_jobs, policy="bs",
                        cadence=cadence)
        return SweepSpec(
            cases=(case,), pon=CFG,
            schedule=TimelineSchedule(n_rounds=n_rounds),
        )

    def test_cadenced_job_sync(self):
        """Offset cadences: jobs 1/2 alternate rounds; job 0 always."""
        spec = self._spec(cadence=[(1, 0), (2, 0), (2, 1)])
        tl = simulate(spec)[0]
        assert len(tl.rounds) == 4
        for r, rnd in enumerate(tl.rounds):
            expect = {0, 1} if r % 2 == 0 else {0, 2}
            assert set(rnd.job_sync) == expect
            assert all(t > 0.0 for t in rnd.job_sync.values())
            assert rnd.sync_time == pytest.approx(
                max(rnd.job_sync.values())
            )

    def test_rounds_match_single_round_runs(self):
        """Independent rounds: round r of the folded timeline equals a
        fresh single-round sweep at stream_round=r."""
        from dataclasses import replace

        case = _mk_case(n_clients=6, n_jobs=2, policy="fcfs")
        spec = SweepSpec(cases=(case,), pon=CFG,
                         schedule=TimelineSchedule(n_rounds=3))
        tl = simulate(spec)[0]
        for r in range(3):
            solo = simulate(SweepSpec(
                cases=(replace(case, stream_round=r),), pon=CFG,
            ))[0]
            assert tl.rounds[r].sync_time == solo.sync_time
            assert tl.rounds[r].job_sync == {
                jid: js.sync_time for jid, js in solo.job_stats.items()
            }

    def test_cadenced_round_matches_oracle(self):
        """A cadenced timeline round equals the oracle run on just
        that round's active jobs (filtered workload, stream_round=r)."""
        from dataclasses import replace

        case = _mk_case(n_clients=6, n_jobs=3, policy="fcfs",
                        cadence=[(1, 0), (2, 0), (2, 1)])
        tl = simulate(SweepSpec(
            cases=(case,), pon=CFG,
            schedule=TimelineSchedule(n_rounds=2),
        ))[0]
        for r in range(2):
            active = tuple(j for j in case.jobs if j.active_in(r))
            keep = {c for j in active for c in j.clients}
            wl = FLRoundWorkload(
                clients=[c for c in case.workload.clients
                         if c.client_id in keep],
                model_bits=case.workload.model_bits,
            )
            ref = simulate_jobs_round_reference(
                CFG, replace(case, workload=wl, jobs=active,
                             stream_round=r),
            )
            for jid, js in ref.job_stats.items():
                assert tl.rounds[r].job_sync[jid] == pytest.approx(
                    js.sync_time, rel=1e-6
                )

    def test_per_round_delegates_to_folded(self):
        case = _mk_case(n_clients=6, n_jobs=2)
        sched = TimelineSchedule(n_rounds=3)
        a = simulate(SweepSpec(cases=(case,), pon=CFG,
                               schedule=sched))[0]
        b = simulate_timeline_per_round(CFG, [case], sched)[0]
        assert [x.sync_time for x in a.rounds] == [
            x.sync_time for x in b.rounds
        ]

    def test_schedule_features_rejected(self):
        case = _mk_case(n_jobs=2)
        sched = TimelineSchedule(
            n_rounds=2, membership=np.ones((2, 6), bool),
        )
        with pytest.raises(ValueError, match="plain schedule"):
            simulate(SweepSpec(cases=(case,), pon=CFG,
                               schedule=sched))

    def test_mixed_sweep_rejected(self):
        tenant = _mk_case(n_jobs=2)
        plain = SweepCase(workload=tenant.workload, load=0.5,
                          policy="fcfs")
        with pytest.raises(ValueError, match="mix"):
            simulate(SweepSpec(
                cases=(tenant, plain), pon=CFG,
                schedule=TimelineSchedule(n_rounds=2),
            ))
